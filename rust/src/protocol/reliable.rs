//! Reliable flooding over lossy links — a robustness extension beyond
//! the paper (which assumes reliable channels, §2).
//!
//! [`crate::network::Network::with_loss`] drops each transmission i.i.d.
//! with probability `p`; [`flood_reliable`] recovers Algorithm 3's
//! delivery guarantee with per-payload acknowledgements and
//! retransmission: every round, each node resends every payload any
//! neighbor has not yet acked. Acks cost 1 point each (they are on-wire
//! traffic too), so the measured overhead vs lossless flooding is
//! `≈ (1 + ack_ratio) / (1 − p)` — quantified in the tests.
//!
//! Payloads are identified by their full [`FloodKey`] `(kind, site,
//! page)`, so a paged coreset exchange retransmits *one lost page*, not
//! the whole portion — the loss-recovery unit shrinks with the page
//! size.

// pallas-lint: allow(no-unordered-iteration, file) — `seen`/`pending` are membership
// structures; every order-sensitive traversal (the send loop, the final held list)
// collects and sorts by FloodKey before any side effect.
// pallas-lint: allow(panic-free-protocol, file) — `seen[&key]` follows the pending
// invariant (an unacked pair implies the payload was recorded) and the flood_key
// expects are checked at origin intake; violations are protocol bugs.
use crate::network::{FloodKey, Network, Payload};
use std::collections::{HashMap, HashSet};

/// Flood one payload per node with retransmission until every node
/// holds every payload.
///
/// Returns per-node held payloads (ordered by origin), like
/// [`crate::protocol::flood`]. Panics if `max_rounds` elapse without
/// global delivery (astronomically unlikely for loss < 1).
pub fn flood_reliable(
    net: &mut Network,
    payloads: Vec<Payload>,
    max_rounds: usize,
) -> Vec<Vec<Payload>> {
    let n = net.n();
    assert_eq!(payloads.len(), n, "one payload per node");
    flood_reliable_multi(
        net,
        payloads.into_iter().map(|p| vec![p]).collect(),
        max_rounds,
    )
}

/// [`flood_reliable`] with any number of payloads per node (e.g. portion
/// pages): ack+retransmit per page until every node holds every page.
pub fn flood_reliable_multi(
    net: &mut Network,
    origins: Vec<Vec<Payload>>,
    max_rounds: usize,
) -> Vec<Vec<Payload>> {
    let n = net.n();
    assert_eq!(origins.len(), n, "one origin set per node");
    let expect: usize = origins.iter().map(|o| o.len()).sum();
    let mut seen: Vec<HashMap<FloodKey, Payload>> = vec![HashMap::new(); n];
    // pending[v]: (key, neighbor) pairs v still needs acked.
    let mut pending: Vec<HashSet<(FloodKey, usize)>> = vec![HashSet::new(); n];

    for (i, own) in origins.into_iter().enumerate() {
        for payload in own {
            let key = payload.flood_key().expect("floodable payload");
            assert_eq!(key.1, i, "payload origin mismatch");
            for &nb in net.graph().neighbors(i) {
                pending[i].insert((key, nb));
            }
            seen[i].insert(key, payload);
        }
    }

    for round in 0..max_rounds {
        // Send every unacked (payload, neighbor) pair. Sorted: HashSet
        // order is per-process random, and under a lossy LinkModel the
        // send order decides which transmissions the loss draws hit —
        // iterating the set directly would leak hash order into results.
        for v in 0..n {
            let mut to_send: Vec<(FloodKey, usize)> = pending[v].iter().copied().collect();
            to_send.sort_unstable();
            for (key, nb) in to_send {
                let payload = seen[v][&key].clone();
                net.send(v, nb, payload);
            }
        }
        if net.step() == 0 && pending.iter().all(|p| p.is_empty()) {
            break;
        }
        // Deliver: record payloads, queue acks; process acks.
        let mut acks: Vec<(usize, usize, FloodKey)> = Vec::new(); // (from, to, key)
        for v in 0..n {
            for (from, payload) in net.recv_all(v) {
                match payload {
                    Payload::Ack { kind, site, page } => {
                        pending[v].remove(&((kind, site, page), from));
                    }
                    other => {
                        let key = other.flood_key().expect("floodable");
                        if !seen[v].contains_key(&key) {
                            for &nb in net.graph().neighbors(v) {
                                if nb != from {
                                    pending[v].insert((key, nb));
                                }
                            }
                            seen[v].insert(key, other);
                        }
                        acks.push((v, from, key));
                    }
                }
            }
        }
        for (from, to, key) in acks {
            net.send(
                from,
                to,
                Payload::Ack {
                    kind: key.0,
                    site: key.1,
                    page: key.2,
                },
            );
        }
        net.step();
        // Deliver acks immediately (they may also be lost).
        for v in 0..n {
            for (from, payload) in net.recv_all(v) {
                if let Payload::Ack { kind, site, page } = payload {
                    pending[v].remove(&((kind, site, page), from));
                }
            }
        }
        let done =
            seen.iter().all(|s| s.len() == expect) && pending.iter().all(|p| p.is_empty());
        if done {
            break;
        }
        assert!(
            round + 1 < max_rounds,
            "flood_reliable: no convergence after {max_rounds} rounds"
        );
    }

    seen.into_iter()
        .enumerate()
        .map(|(v, s)| {
            assert_eq!(s.len(), expect, "node {v} missing payloads");
            let mut held: Vec<Payload> = s.into_values().collect();
            held.sort_by_key(|p| p.flood_key().unwrap());
            held
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::network::{paginate, reassemble};
    use crate::points::WeightedSet;
    use crate::protocol::flood;
    use crate::rng::Pcg64;
    use crate::topology::generators;
    use std::sync::Arc;

    fn unit_payloads(n: usize) -> Vec<Payload> {
        (0..n)
            .map(|i| Payload::LocalCost {
                site: i,
                cost: i as f64,
            })
            .collect()
    }

    #[test]
    fn lossless_matches_plain_flooding_delivery() {
        let g = generators::grid(3, 3);
        let mut net = Network::new(g.clone());
        let held = flood_reliable(&mut net, unit_payloads(9), 100);
        for h in &held {
            assert_eq!(h.len(), 9);
        }
        // Lossless cost sits between plain flooding (reliable skips the
        // send-back-to-sender of Algorithm 3 but adds one ack per
        // delivery) and 2x plain flooding.
        let mut net_plain = Network::new(g);
        flood(&mut net_plain, unit_payloads(9));
        assert!(
            net.cost_points() > net_plain.cost_points()
                && net.cost_points() <= 2 * net_plain.cost_points(),
            "reliable {} vs plain {}",
            net.cost_points(),
            net_plain.cost_points()
        );
    }

    #[test]
    fn delivers_under_heavy_loss() {
        let mut rng = Pcg64::seed_from(5);
        for p in [0.1, 0.3, 0.5] {
            let g = generators::erdos_renyi_connected(&mut rng, 12, 0.3);
            let mut net = Network::new(g).with_loss(p, 99);
            let held = flood_reliable(&mut net, unit_payloads(12), 10_000);
            for h in &held {
                assert_eq!(h.len(), 12, "loss={p}");
            }
        }
    }

    #[test]
    fn overhead_grows_with_loss() {
        let g = generators::grid(3, 3);
        let mut net0 = Network::new(g.clone());
        flood_reliable(&mut net0, unit_payloads(9), 10_000);
        let mut net3 = Network::new(g).with_loss(0.3, 7);
        flood_reliable(&mut net3, unit_payloads(9), 10_000);
        assert!(
            net3.cost_points() > net0.cost_points(),
            "loss must cost retransmissions: {} !> {}",
            net3.cost_points(),
            net0.cost_points()
        );
    }

    #[test]
    #[should_panic(expected = "no convergence")]
    fn total_loss_panics_with_bound() {
        let g = generators::path(3);
        let mut net = Network::new(g).with_loss(1.0, 1);
        flood_reliable(&mut net, unit_payloads(3), 50);
    }

    #[test]
    fn lost_pages_are_retransmitted_individually_and_reassemble() {
        let mut rng = Pcg64::seed_from(8);
        let g = generators::grid(2, 3);
        let portions: Vec<Arc<WeightedSet>> = (0..6)
            .map(|_| {
                let mut s = WeightedSet::empty(2);
                for _ in 0..12 {
                    s.push(&[rng.normal() as f32, rng.normal() as f32], 1.0);
                }
                Arc::new(s)
            })
            .collect();
        let origins: Vec<Vec<Payload>> = portions
            .iter()
            .enumerate()
            .map(|(i, p)| paginate(i, p.clone(), 4))
            .collect();
        let mut net = Network::new(g).with_loss(0.25, 42);
        let held = flood_reliable_multi(&mut net, origins, 10_000);
        for h in held {
            let back = reassemble(&h).unwrap();
            assert_eq!(back.len(), 6);
            for (site, set) in back {
                assert_eq!(set, *portions[site], "site {site} torn after loss");
            }
        }
        assert!(net.dropped() > 0, "loss must have bitten for this test to mean anything");
    }
}
