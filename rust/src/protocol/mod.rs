//! Distributed protocols (§4): flooding message-passing on general
//! graphs (Algorithm 3), rooted-tree aggregation (Theorem 3), and the
//! end-to-end distributed clustering drivers (Algorithm 2) that tie the
//! coreset construction, the network simulator and the solvers together.

mod distributed_clustering;
mod flooding;
mod reliable;
mod tree;

pub use distributed_clustering::{
    cluster_on_graph, cluster_on_graph_exec, cluster_on_tree, cluster_on_tree_exec,
    combine_on_graph, combine_on_tree, zhang_on_tree, RunResult,
};
pub use flooding::flood;
pub use reliable::flood_reliable;
pub use tree::{broadcast_down, converge_cast};
