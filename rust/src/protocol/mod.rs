//! Distributed protocols (§4): flooding message-passing on general
//! graphs (Algorithm 3), rooted-tree aggregation (Theorem 3), and the
//! end-to-end distributed clustering driver (Algorithm 2) that ties the
//! coreset construction, the paged streaming message plane and the
//! solvers together.
//!
//! Every primitive is a per-node state machine under one synchronous
//! round loop (`session`), so the cost exchange, the paged coreset
//! streaming and the solution broadcast overlap in simulated time
//! instead of running as global barriers.

mod distributed_clustering;
mod flooding;
mod reliable;
mod session;
mod tree;

pub use distributed_clustering::{
    cluster_on_graph, cluster_on_graph_exec, cluster_on_tree, cluster_on_tree_exec,
    combine_on_graph, combine_on_tree, run_pipeline, zhang_on_tree, zhang_on_tree_exec,
    CoresetPlan, RunResult, Topology,
};
pub use flooding::{flood, flood_multi};
pub use reliable::{flood_reliable, flood_reliable_multi};
pub use tree::{broadcast_down, converge_cast, converge_cast_multi};
