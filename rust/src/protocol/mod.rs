//! Distributed protocols (§4): flooding message-passing on general
//! graphs (Algorithm 3), rooted-tree aggregation (Theorem 3), and the
//! end-to-end distributed clustering engine (Algorithm 2) that ties the
//! coreset construction, the paged streaming message plane and the
//! solvers together.
//!
//! Every primitive is a per-node state machine under one synchronous
//! round loop (`session`), so the cost exchange, the paged coreset
//! streaming and the solution broadcast overlap in simulated time
//! instead of running as global barriers.
//!
//! Runs are constructed through the typed
//! [`Scenario`](crate::scenario::Scenario) builder; the `cluster_on_*`
//! family kept here are bit-compatible shims over it.

mod distributed_clustering;
mod flooding;
mod reliable;
pub(crate) mod session;
mod tree;

pub use distributed_clustering::{
    cluster_on_graph, cluster_on_graph_exec, cluster_on_tree, cluster_on_tree_exec,
    combine_on_graph, combine_on_tree, zhang_on_tree, zhang_on_tree_exec, RunResult, Topology,
};
pub(crate) use distributed_clustering::{run_composed, stream_exchange};
pub use flooding::{flood, flood_multi, flood_multi_mode};
pub use session::{DriveMode, DriveStats};
pub use reliable::{flood_reliable, flood_reliable_multi};
pub use tree::{broadcast_down, converge_cast, converge_cast_multi};
