//! The unified protocol engine: per-node state machines driven by one
//! synchronous round loop.
//!
//! Every protocol primitive (flooding, converge-cast, broadcast) and the
//! end-to-end clustering pipeline are expressed as [`NodeMachine`]s: a
//! machine reacts to delivered messages and to the start of each round,
//! and queues sends through an [`Outbox`]. [`drive`] owns the loop —
//! tick every node, advance the simulator one round, deliver — so
//! *phases overlap naturally*: a site whose inputs arrived early starts
//! its next phase while slower parts of the network are still busy
//! (e.g. Round-2 portion pages enter the network while the Round-1 cost
//! flood is still propagating elsewhere), and a capacity-limited
//! [`LinkModel`](crate::network::LinkModel) back-pressures everything
//! without any machine having to know about it.
//!
//! Collector-side buffering goes through the mergeable-sketch subsystem
//! ([`crate::sketch`]): a [`PipeMachine`] folds arriving portion pages
//! into its [`Sketch`] the moment they land and solves on `finish()`,
//! so the collector never materializes more than the sketch's resident
//! set — and, in merge-and-reduce mode on a tree, relay nodes reduce
//! their children's streams *in-network* before forwarding, shrinking
//! both upstream traffic and per-node peaks. Each machine meters its own
//! buffer high-water mark ([`PipeMachine::node_peak`]) — the host-side
//! counterpart of the wire-side
//! [`Network::peak_points`](crate::network::Network::peak_points).
//!
//! All machine logic runs on the driver thread and is a pure function of
//! the message history, so `rounds`, `cost_points` and `peak_points` are
//! bit-identical for any worker-thread count of the compute layer.

// pallas-lint: allow(no-unordered-iteration, file) — the HashSets here are dedup
// membership sets (seen flood keys, acked pages): insert/contains/len only, never
// iterated, so hash order cannot reach any observable result.
// pallas-lint: allow(panic-free-protocol, file) — role panics (reparent/adopt on the
// wrong machine kind) are documented caller bugs; the expects decode machine-built
// messages whose shape the sending state machine just constructed.
use crate::clustering::backend::Backend;
use crate::clustering::{approx_solution, Objective, Solution};
use crate::coreset::Coreset;
use crate::network::{paginate, FloodKey, Network, Payload};
use crate::rng::Pcg64;
use crate::sketch::Sketch;
use crate::topology::Graph;
use crate::trace::{Phase, Tracer};
use std::collections::HashSet;
use std::sync::Arc;

/// Sends queued by a machine during one callback: `(to, payload)`.
#[derive(Default)]
pub(crate) struct Outbox {
    pub(crate) sends: Vec<(usize, Payload)>,
}

impl Outbox {
    /// Queue one send.
    pub(crate) fn send(&mut self, to: usize, payload: Payload) {
        self.sends.push((to, payload));
    }

    /// Queue a clone per neighbor (payloads are `Arc`-backed: O(1) each).
    pub(crate) fn broadcast(&mut self, neigh: &[usize], payload: &Payload) {
        for &to in neigh {
            self.sends.push((to, payload.clone()));
        }
    }
}

/// One node's protocol logic.
pub(crate) trait NodeMachine {
    /// Start-of-round hook. First invocation doubles as initialization
    /// (machines drain their origin payloads then); later invocations
    /// flush whatever earlier deliveries made sendable.
    fn tick(&mut self, out: &mut Outbox);

    /// One message delivered to this node in the round just stepped.
    fn on_msg(&mut self, from: usize, msg: Payload, out: &mut Outbox);

    /// True when the next `tick` would act even without a new delivery —
    /// the active-set drive loop keeps such nodes scheduled. The default
    /// (false) is correct for machines whose ticks are no-ops absent new
    /// input; machines holding deferred work must override it.
    fn wants_tick(&self) -> bool {
        false
    }
}

/// Scheduling strategy for [`drive_with_mode`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum DriveMode {
    /// Event-driven: after the initialization round, a node is ticked
    /// only when the last round delivered to it or its machine signals
    /// [`NodeMachine::wants_tick`]. Per-round scheduling work is
    /// O(active frontier), not O(n). The default.
    #[default]
    ActiveSet,
    /// The dense reference loop: tick all `n` nodes every round and
    /// scan all `n` inboxes. Semantically identical (skipped ticks are
    /// no-ops); kept as the bit-identity oracle for the equivalence
    /// suite.
    Dense,
}

/// Scheduling-work meters reported by the drive loop.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DriveStats {
    /// Node ticks executed across the run (dense mode: `n × rounds`;
    /// active-set mode: the sum of per-round frontier sizes).
    pub node_ticks: u64,
    /// Rounds the loop ran, including the final empty round that
    /// detects quiescence.
    pub rounds: u64,
}

/// Run machines to quiescence with the default [`DriveMode::ActiveSet`]
/// scheduling: tick the scheduled nodes, advance one synchronous round,
/// deliver. Terminates when a round moves no messages — by then no
/// machine has pending sends (ticks already ran) and the simulator is
/// drained.
pub(crate) fn drive<M: NodeMachine>(net: &mut Network, nodes: &mut [M]) -> DriveStats {
    drive_with_mode(net, nodes, DriveMode::ActiveSet)
}

/// [`drive`] with an explicit scheduling mode.
///
/// Both modes produce bit-identical transcripts, comm totals, rounds
/// and RNG draw orders: the first round ticks every node (first ticks
/// double as initialization), and afterwards a tick can only act on
/// state changed by `on_msg` — whose node was delivered to, and is
/// therefore scheduled — or flagged via [`NodeMachine::wants_tick`].
/// The active set is processed in ascending node id (debug-asserted),
/// matching the dense loop's `0..n` scan order exactly.
pub(crate) fn drive_with_mode<M: NodeMachine>(
    net: &mut Network,
    nodes: &mut [M],
    mode: DriveMode,
) -> DriveStats {
    let n = nodes.len();
    assert_eq!(net.n(), n, "one machine per node");
    let mut stats = DriveStats::default();
    let mut active: Vec<usize> = (0..n).collect();
    loop {
        debug_assert!(
            active.windows(2).all(|w| w[0] < w[1]),
            "active set must be ascending and deduplicated"
        );
        stats.node_ticks += active.len() as u64;
        for &v in &active {
            let mut out = Outbox::default();
            nodes[v].tick(&mut out);
            for (to, p) in out.sends {
                net.send(v, to, p);
            }
        }
        stats.rounds += 1;
        if net.step() == 0 {
            break;
        }
        match mode {
            DriveMode::Dense => {
                for v in 0..n {
                    for (from, p) in net.recv_all(v) {
                        let mut out = Outbox::default();
                        nodes[v].on_msg(from, p, &mut out);
                        for (to, q) in out.sends {
                            net.send(v, to, q);
                        }
                    }
                }
                active = (0..n).collect();
            }
            DriveMode::ActiveSet => {
                // The simulator's delivered set is already ascending
                // and deduplicated — O(frontier), no O(n) scan.
                let delivered: Vec<usize> = net.delivered_nodes().to_vec();
                for &v in &delivered {
                    for (from, p) in net.recv_all(v) {
                        let mut out = Outbox::default();
                        nodes[v].on_msg(from, p, &mut out);
                        for (to, q) in out.sends {
                            net.send(v, to, q);
                        }
                    }
                }
                // Next frontier: delivered nodes, plus any node ticked
                // this round whose machine still holds deferred work.
                let mut next = delivered;
                next.extend(active.iter().copied().filter(|&v| nodes[v].wants_tick()));
                next.sort_unstable();
                next.dedup();
                active = next;
            }
        }
    }
    stats
}

// ---------------------------------------------------------------------
// Primitive machines
// ---------------------------------------------------------------------

/// Algorithm 3 flooding: originate payloads, forward each distinct key
/// to every neighbor exactly once. Holds the shared CSR graph, so its
/// broadcasts read the zero-alloc neighbor slice instead of a per-node
/// copy of the adjacency.
pub(crate) struct FloodMachine {
    graph: Arc<Graph>,
    id: usize,
    origin: Vec<Payload>,
    seen: HashSet<FloodKey>,
    /// Every payload this node ended up holding (its own included).
    pub(crate) held: Vec<Payload>,
}

impl FloodMachine {
    pub(crate) fn new(graph: Arc<Graph>, id: usize, origin: Vec<Payload>) -> Self {
        FloodMachine {
            graph,
            id,
            origin,
            seen: HashSet::new(),
            held: Vec::new(),
        }
    }
}

impl NodeMachine for FloodMachine {
    fn tick(&mut self, out: &mut Outbox) {
        for p in self.origin.drain(..) {
            let key = p.flood_key().expect("flooded payloads must have an origin");
            self.seen.insert(key);
            out.broadcast(self.graph.neighbors(self.id), &p);
            self.held.push(p);
        }
    }

    fn on_msg(&mut self, _from: usize, msg: Payload, out: &mut Outbox) {
        let key = msg.flood_key().expect("floodable");
        if self.seen.insert(key) {
            out.broadcast(self.graph.neighbors(self.id), &msg);
            self.held.push(msg);
        }
    }

    fn wants_tick(&self) -> bool {
        !self.origin.is_empty()
    }
}

/// Theorem 3 converge-cast: relay every payload one hop toward the root
/// per round.
pub(crate) struct ConvergeMachine {
    /// `None` at the root.
    parent: Option<usize>,
    relay: Vec<Payload>,
    /// Root only: everything that arrived (its own payloads included).
    pub(crate) collected: Vec<Payload>,
}

impl ConvergeMachine {
    pub(crate) fn new(parent: Option<usize>, own: Vec<Payload>) -> Self {
        let (relay, collected) = if parent.is_some() {
            (own, Vec::new())
        } else {
            (Vec::new(), own)
        };
        ConvergeMachine {
            parent,
            relay,
            collected,
        }
    }
}

impl NodeMachine for ConvergeMachine {
    fn tick(&mut self, out: &mut Outbox) {
        if let Some(parent) = self.parent {
            for p in self.relay.drain(..) {
                out.send(parent, p);
            }
        }
    }

    fn on_msg(&mut self, _from: usize, msg: Payload, _out: &mut Outbox) {
        if self.parent.is_none() {
            self.collected.push(msg);
        } else {
            self.relay.push(msg);
        }
    }

    fn wants_tick(&self) -> bool {
        !self.relay.is_empty()
    }
}

/// Root-to-leaves broadcast: each tree edge carries the payload once.
pub(crate) struct BroadcastMachine {
    children: Vec<usize>,
    /// Root's payload, emitted on the first tick.
    origin: Option<Payload>,
    /// Set once the payload reached this node (true at the root).
    pub(crate) received: bool,
}

impl BroadcastMachine {
    pub(crate) fn new(children: Vec<usize>, origin: Option<Payload>) -> Self {
        let received = origin.is_some();
        BroadcastMachine {
            children,
            origin,
            received,
        }
    }
}

impl NodeMachine for BroadcastMachine {
    fn tick(&mut self, out: &mut Outbox) {
        if let Some(p) = self.origin.take() {
            for &c in &self.children {
                out.send(c, p.clone());
            }
        }
    }

    fn on_msg(&mut self, _from: usize, msg: Payload, out: &mut Outbox) {
        self.received = true;
        for &c in &self.children {
            out.send(c, msg.clone());
        }
    }

    fn wants_tick(&self) -> bool {
        self.origin.is_some()
    }
}

/// Zhang-et-al. summary converge-cast: every node waits until each of
/// its children's (already size-accounted) summaries arrived, then emits
/// its own toward the root — so nodes at the same depth transfer
/// *concurrently* and `rounds` reflects pipelined tree levels, not one
/// synchronous step per edge.
pub(crate) struct ZhangMachine {
    /// `None` at the root.
    parent: Option<usize>,
    /// Child summaries still outstanding.
    pending_children: usize,
    /// This node's metering payload (`None` at the root).
    summary: Option<Payload>,
    sent: bool,
}

impl ZhangMachine {
    pub(crate) fn new(
        parent: Option<usize>,
        n_children: usize,
        summary: Option<Payload>,
    ) -> Self {
        ZhangMachine {
            parent,
            pending_children: n_children,
            summary,
            sent: false,
        }
    }
}

impl NodeMachine for ZhangMachine {
    fn tick(&mut self, out: &mut Outbox) {
        if !self.sent && self.pending_children == 0 {
            self.sent = true;
            if let (Some(parent), Some(p)) = (self.parent, self.summary.take()) {
                out.send(parent, p);
            }
        }
    }

    fn on_msg(&mut self, _from: usize, msg: Payload, _out: &mut Outbox) {
        debug_assert!(
            matches!(msg, Payload::Opaque { .. }),
            "zhang converge-cast carries metering payloads only"
        );
        self.pending_children -= 1;
    }

    fn wants_tick(&self) -> bool {
        !self.sent && self.pending_children == 0
    }
}

// ---------------------------------------------------------------------
// End-to-end pipeline machine (Algorithm 2 over either topology)
// ---------------------------------------------------------------------

/// How a pipeline node is wired into the topology. Graph-flooding
/// roles hold the shared CSR graph and read their neighbor slice
/// through it — no per-node adjacency copies.
pub(crate) enum PipeRole {
    /// General graph: flood everything to everyone.
    Graph {
        /// Shared topology (this node broadcasts to its CSR slice).
        graph: Arc<Graph>,
    },
    /// Rooted spanning tree: converge up, broadcast down.
    Tree {
        /// `None` at the root.
        parent: Option<usize>,
        /// Children, ascending node id.
        children: Vec<usize>,
    },
    /// Overlay-reduced graph exchange: the cost exchange floods the
    /// *graph*, portions converge-fold up a spanning-tree *overlay*
    /// (every overlay edge is a graph edge, so the underlying per-edge
    /// link capacities apply unchanged), and the root floods only its
    /// reduced set + the centers back over the graph edges.
    Overlay {
        /// Overlay parent (`None` at the overlay root).
        parent: Option<usize>,
        /// Shared *graph* topology (cost flood + reduced-set flood).
        graph: Arc<Graph>,
    },
}

/// The final-solve hook a collector machine runs when its fold
/// completes: the backend and (mutably borrowed) pipeline RNG, so the
/// solve consumes exactly the draws the materialized driver consumed —
/// bit-compatibility of exact mode hinges on this.
pub(crate) struct Solver<'a> {
    pub(crate) backend: &'a dyn Backend,
    pub(crate) rng: &'a mut Pcg64,
    pub(crate) k: usize,
    pub(crate) objective: Objective,
    pub(crate) iters: usize,
}

/// Per-node state machine of the unified clustering pipeline.
///
/// Phases per node — each entered as soon as *this node's* inputs are
/// complete, regardless of global progress:
///
/// 1. cost exchange (optional; the paper's Round 1 scalar): graph nodes
///    flood their `LocalCost`, tree nodes relay costs to the root, which
///    answers with the `Scalar` total;
/// 2. portion streaming: once *ready* (all costs seen on a graph / total
///    received on a tree / immediately when the plan needs no cost
///    exchange), the node emits its portion pages — overlapping with
///    cost traffic still propagating elsewhere. Folding nodes insert
///    every page (their own included) into their [`Sketch`] on arrival;
/// 3. completion: a *reducing relay* (tree, merge-and-reduce mode)
///    finishes its sketch once its own portion and every child's stream
///    are complete, re-paginates the reduced set under its own site id
///    and sends it upstream; the *collector* finishes its sketch, runs
///    the final solve ([`Solver`]) and — on a tree — broadcasts the
///    `Centers` down. The overlay role ([`PipeRole::Overlay`]) composes
///    the tree fold with graph flooding in the same phases: costs flood
///    the graph, reduced streams converge up the overlay, and the root
///    floods only its reduced set + centers back over the graph.
pub(crate) struct PipeMachine<'a> {
    /// This node's id (site id of re-paginated reduced streams).
    id: usize,
    role: PipeRole,
    /// Own `LocalCost`, emitted on the first tick (None: no cost phase).
    cost: Option<Payload>,
    /// Distinct cost keys seen (graph: dedup+count; tree root: count).
    costs_seen: HashSet<FloodKey>,
    /// Cost keys required before this node/root proceeds (0 = no cost
    /// phase).
    costs_expected: usize,
    /// Tree: payloads waiting to move one hop toward the root.
    relay_up: Vec<Payload>,
    /// Points currently buffered in `relay_up`.
    relay_points: usize,
    /// Tree root: `Scalar` budget total, broadcast when costs complete.
    total: Option<Payload>,
    /// This node may emit its own pages.
    ready: bool,
    launched: bool,
    /// Own portion pages.
    pages: Vec<Payload>,
    /// Graph: distinct page keys seen (flooding dedup).
    pages_seen: HashSet<FloodKey>,
    /// Where pages land on folding nodes (None: verbatim relay).
    fold: Option<Sketch<'a>>,
    /// Distinct pages folded so far.
    pages_folded: usize,
    /// Count-based completion: pages that complete the collection
    /// (`usize::MAX`: completion is site-based or this node never
    /// completes).
    pages_expected: usize,
    /// Site-based completion (tree merge-and-reduce): own portion plus
    /// one reduced portion per child (0 = not site-based).
    sites_expected: usize,
    /// Tree non-root in merge-and-reduce mode: on completion, finish the
    /// sketch and send the reduced stream to the parent.
    reduce_relay: bool,
    /// Page size for re-paginated reduced streams.
    page_points: usize,
    /// Collector only: the final-solve hook.
    solver: Option<Solver<'a>>,
    /// Completion actions have run.
    done: bool,
    /// Collector output, readable after [`drive`] returns.
    pub(crate) solution: Option<Solution>,
    /// Collector's finished fold, readable after [`drive`] returns.
    pub(crate) finished: Option<Coreset>,
    /// High-water mark of points buffered in this machine (sketch
    /// residency + relay backlog) — the node-side memory meter.
    pub(crate) node_peak: usize,
    /// This node's sketch's measured composed error factor, captured
    /// when its fold completes (1.0 for exact folds and pure relays).
    pub(crate) sketch_error_factor: f64,
    /// Bucket reductions this node's sketch performed.
    pub(crate) sketch_reductions: usize,
    /// Overlay: this node received (or, at the root, originated) the
    /// final `Centers` flood.
    pub(crate) centers_got: bool,
    /// Overlay: distinct reduced-set flood pages this node holds.
    pub(crate) bcast_pages_got: usize,
    /// Overlay: total pages of the root's reduced-set flood (learned
    /// from the page headers; authoritative at the root).
    pub(crate) bcast_pages_total: usize,
    /// Service failover: a failed node never ticks, sends nothing, and
    /// silently drops whatever is still in flight toward it.
    failed: bool,
    /// Phase-span observer (counts only; never alters behavior or RNG).
    tracer: Option<Tracer>,
}

impl<'a> PipeMachine<'a> {
    /// Graph-mode node. `cost` is `None` for plans without a cost
    /// exchange (then the node is ready immediately). A graph node with
    /// `fold` collects the full flooded stream into its sketch
    /// (Algorithm 2: any node could); one without only dedups and
    /// forwards, counting the distinct pages it observed. `solver` is
    /// set on the collector only.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn graph(
        id: usize,
        graph: Arc<Graph>,
        cost: Option<Payload>,
        pages: Vec<Payload>,
        n_nodes: usize,
        pages_expected: usize,
        fold: Option<Sketch<'a>>,
        solver: Option<Solver<'a>>,
    ) -> Self {
        let has_cost = cost.is_some();
        PipeMachine {
            id,
            role: PipeRole::Graph { graph },
            cost,
            costs_seen: HashSet::new(),
            costs_expected: if has_cost { n_nodes } else { 0 },
            relay_up: Vec::new(),
            relay_points: 0,
            total: None,
            ready: !has_cost,
            launched: false,
            pages,
            pages_seen: HashSet::new(),
            fold,
            pages_folded: 0,
            pages_expected,
            sites_expected: 0,
            reduce_relay: false,
            page_points: 0,
            solver,
            done: false,
            solution: None,
            finished: None,
            node_peak: 0,
            sketch_error_factor: 1.0,
            sketch_reductions: 0,
            centers_got: false,
            bcast_pages_got: 0,
            bcast_pages_total: 0,
            failed: false,
            tracer: None,
        }
    }

    /// Tree-mode node. Only the root takes `total`, a `solver` and a
    /// nonzero `costs_expected`. `fold`/`sites_expected`/`reduce_relay`
    /// select between verbatim relaying (exact mode, non-root), folding
    /// with count-based completion (exact root) and folding with
    /// site-based completion plus upstream reduction (merge-and-reduce).
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn tree(
        id: usize,
        parent: Option<usize>,
        children: Vec<usize>,
        cost: Option<Payload>,
        total: Option<Payload>,
        pages: Vec<Payload>,
        n_nodes: usize,
        fold: Option<Sketch<'a>>,
        pages_expected: usize,
        sites_expected: usize,
        reduce_relay: bool,
        page_points: usize,
        solver: Option<Solver<'a>>,
    ) -> Self {
        let has_cost = cost.is_some();
        let is_root = parent.is_none();
        PipeMachine {
            id,
            role: PipeRole::Tree { parent, children },
            cost,
            costs_seen: HashSet::new(),
            costs_expected: if has_cost && is_root { n_nodes } else { 0 },
            relay_up: Vec::new(),
            relay_points: 0,
            total,
            // Roots without a cost phase are ready at once; non-roots
            // without a cost phase likewise. With a cost phase everyone
            // waits (the root for the full count, others for the total).
            ready: !has_cost,
            launched: false,
            pages,
            pages_seen: HashSet::new(),
            fold,
            pages_folded: 0,
            pages_expected,
            sites_expected,
            reduce_relay,
            page_points,
            solver,
            done: false,
            solution: None,
            finished: None,
            node_peak: 0,
            sketch_error_factor: 1.0,
            sketch_reductions: 0,
            centers_got: false,
            bcast_pages_got: 0,
            bcast_pages_total: 0,
            failed: false,
            tracer: None,
        }
    }

    /// Overlay-mode node: cost exchange floods the graph (readiness
    /// gating exactly as in graph mode), the node folds its own portion
    /// plus one reduced portion per overlay child into its sketch
    /// (site-based completion — an empty site's single zero-cost page
    /// still completes its site through the sketch's page tracker), and
    /// on completion a non-root re-paginates its reduced sketch under
    /// its own id toward the overlay parent while the root solves and
    /// floods only the reduced set + centers over the graph edges.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn overlay(
        id: usize,
        parent: Option<usize>,
        graph: Arc<Graph>,
        cost: Option<Payload>,
        pages: Vec<Payload>,
        n_nodes: usize,
        fold: Option<Sketch<'a>>,
        sites_expected: usize,
        page_points: usize,
        solver: Option<Solver<'a>>,
    ) -> Self {
        let has_cost = cost.is_some();
        let reduce_relay = parent.is_some();
        PipeMachine {
            id,
            role: PipeRole::Overlay { parent, graph },
            cost,
            costs_seen: HashSet::new(),
            costs_expected: if has_cost { n_nodes } else { 0 },
            relay_up: Vec::new(),
            relay_points: 0,
            total: None,
            ready: !has_cost,
            launched: false,
            pages,
            // Reused for the reduced-set flood dedup on the way back.
            pages_seen: HashSet::new(),
            fold,
            pages_folded: 0,
            pages_expected: usize::MAX,
            sites_expected,
            reduce_relay,
            page_points,
            solver,
            done: false,
            solution: None,
            finished: None,
            node_peak: 0,
            sketch_error_factor: 1.0,
            sketch_reductions: 0,
            centers_got: false,
            bcast_pages_got: 0,
            bcast_pages_total: 0,
            failed: false,
            tracer: None,
        }
    }

    /// Distinct portion pages this node folded (graph nodes fold the
    /// whole flooded stream; the driver checks everyone saw everything).
    pub(crate) fn pages_collected(&self) -> usize {
        self.pages_folded
    }

    // -----------------------------------------------------------------
    // Service failover: the re-parent path. The service layer detects a
    // relay failure at an epoch boundary, surgically rewires the
    // machines *before* the drive (fail the dead node, move each orphan
    // under a surviving neighbor, fix the completion targets), and the
    // re-merge of the affected subtree then runs inside the ordinary
    // session drive loop — no special-cased recovery protocol.
    // -----------------------------------------------------------------

    /// Mark this node failed: it never ticks, sends nothing, and
    /// silently drops anything still in flight toward it.
    pub(crate) fn fail(&mut self) {
        self.failed = true;
    }

    /// Re-target this node's tree parent (an orphan adopted by a
    /// surviving neighbor). Tree role only — graph machines have no
    /// parent to move.
    pub(crate) fn reparent(&mut self, new_parent: Option<usize>) {
        match &mut self.role {
            PipeRole::Tree { parent, .. } | PipeRole::Overlay { parent, .. } => {
                *parent = new_parent;
            }
            PipeRole::Graph { .. } => panic!("reparent on a graph-mode machine"),
        }
    }

    /// Adopt an orphan as a child (tree role). Under site-based
    /// completion the fold now also waits for the orphan's reduced
    /// stream, so `sites_expected` grows with the child list.
    pub(crate) fn adopt_child(&mut self, child: usize) {
        let PipeRole::Tree { children, .. } = &mut self.role else {
            panic!("adopt_child on a non-tree machine");
        };
        if !children.contains(&child) {
            children.push(child);
            children.sort_unstable();
            if self.sites_expected > 0 {
                self.sites_expected += 1;
            }
        }
    }

    /// Forget a (failed) child: its reduced stream will never arrive,
    /// so under site-based completion the fold stops waiting for it.
    pub(crate) fn drop_child(&mut self, child: usize) {
        let PipeRole::Tree { children, .. } = &mut self.role else {
            panic!("drop_child on a non-tree machine");
        };
        let before = children.len();
        children.retain(|&c| c != child);
        if children.len() < before && self.sites_expected > 0 {
            self.sites_expected -= 1;
        }
    }

    /// Extract this node's fold after the drive. Recovery sessions run
    /// the root with neither a solver nor `reduce_relay`, so its
    /// completed fold stays in place for the service to finish
    /// host-side.
    pub(crate) fn take_fold(&mut self) -> Option<Sketch<'a>> {
        self.fold.take()
    }

    /// Attach a [`Tracer`]: the machine emits per-node phase enter/exit
    /// events at its existing state flips (cost-ready, fold-complete,
    /// solve, centers receipt) and wires the same tracer into its
    /// sketch for reduction events. Observation only — no state flip,
    /// send or RNG draw changes, so traced runs stay bit-identical.
    pub(crate) fn with_tracer(mut self, tracer: Option<Tracer>) -> Self {
        if let Some(t) = &tracer {
            if let Some(f) = &mut self.fold {
                f.set_tracer(t.clone(), self.id);
            }
            // The node starts inside whichever phase its readiness
            // implies: waiting on the cost exchange, or (plans without
            // one) streaming portions immediately.
            let phase = if self.ready {
                Phase::ConvergeFold
            } else {
                Phase::CostFlood
            };
            t.phase(self.id, phase, true);
        }
        self.tracer = tracer;
        self
    }

    /// Emit one phase enter/exit event for this node, if tracing.
    fn trace_phase(&self, phase: Phase, enter: bool) {
        if let Some(t) = &self.tracer {
            t.phase(self.id, phase, enter);
        }
    }

    /// The cost exchange just completed for this node: close the
    /// cost-flood span and open the converge-fold span.
    fn trace_ready_flip(&self) {
        self.trace_phase(Phase::CostFlood, false);
        self.trace_phase(Phase::ConvergeFold, true);
    }

    fn bump_peak(&mut self) {
        // The sketch meters its own transient high-water mark (a carry
        // briefly holds a merged bucket before reducing it), so the node
        // peak is the max of the buffer view and the sketch's internal
        // peak.
        let fold_now = self.fold.as_ref().map_or(0, |f| f.points_held());
        let fold_peak = self.fold.as_ref().map_or(0, |f| f.peak_points());
        self.node_peak = self
            .node_peak
            .max(self.relay_points + fold_now)
            .max(fold_peak);
    }

    fn collection_complete(&self) -> bool {
        if self.pages_expected != usize::MAX {
            self.pages_folded == self.pages_expected
        } else if self.sites_expected > 0 {
            self.fold
                .as_ref()
                .is_some_and(|f| f.complete_sites() == self.sites_expected)
        } else {
            false // pure relay: nothing to complete
        }
    }

    fn launch(&mut self, out: &mut Outbox) {
        self.launched = true;
        let pages = std::mem::take(&mut self.pages);
        if let PipeRole::Graph { graph } = &self.role {
            for p in pages {
                self.pages_seen.insert(p.flood_key().expect("page key"));
                out.broadcast(graph.neighbors(self.id), &p);
                fold_page(&mut self.fold, &mut self.pages_folded, &p);
            }
        } else if self.fold.is_some() {
            // Folding tree/overlay node (root, or reducing relay): own
            // pages go straight into the sketch — they never hit the
            // wire under their own ids.
            for p in pages {
                fold_page(&mut self.fold, &mut self.pages_folded, &p);
            }
        } else {
            // Verbatim relay: own pages head for the root.
            for p in pages {
                self.relay_points += p.size_points();
                self.relay_up.push(p);
            }
        }
        self.bump_peak();
    }

    /// Completion actions: reducing relays ship their finished sketch
    /// upstream; the collector solves and (on a tree) broadcasts.
    fn on_complete(&mut self, out: &mut Outbox) {
        self.bump_peak(); // capture the fold's peak before consuming it
        if let Some(fold) = &self.fold {
            // Error accounting, captured before the fold is consumed:
            // the driver composes these per-node factors along the
            // relay chains into the run-level meter.
            self.sketch_error_factor = fold.error_factor();
            self.sketch_reductions = fold.reductions();
        }
        if self.reduce_relay {
            let sketch = self.fold.take().expect("reducing relay folds");
            let reduced = sketch
                .finish()
                .expect("site-based completion implies untorn portions");
            let parent = match self.role {
                PipeRole::Tree {
                    parent: Some(p), ..
                }
                | PipeRole::Overlay {
                    parent: Some(p), ..
                } => Some(p),
                _ => None,
            };
            if let Some(parent) = parent {
                for p in paginate(self.id, Arc::new(reduced), self.page_points) {
                    out.send(parent, p);
                }
            }
            return;
        }
        if let Some(solver) = self.solver.take() {
            let sketch = self.fold.take().expect("collector folds");
            let set = sketch
                .finish()
                .expect("completed collection implies untorn portions");
            let coreset = Coreset {
                sampled: set.n(),
                set,
            };
            self.trace_phase(Phase::Solve, true);
            let sol = approx_solution(
                &coreset.set,
                solver.k,
                solver.objective,
                solver.backend,
                solver.rng,
                solver.iters,
            );
            self.trace_phase(Phase::Solve, false);
            match &self.role {
                PipeRole::Tree { children, .. } => {
                    let payload = Payload::Centers(Arc::new(sol.centers.clone()));
                    for &c in children {
                        out.send(c, payload.clone());
                    }
                    self.trace_phase(Phase::Broadcast, true);
                }
                PipeRole::Overlay { graph, .. } => {
                    // Flood ONLY the reduced root set + the centers back
                    // over the graph edges — the full stream never
                    // floods. Seeding `pages_seen` keeps echoes from
                    // re-flooding at the root.
                    let pages =
                        paginate(self.id, Arc::new(coreset.set.clone()), self.page_points);
                    self.bcast_pages_total = pages.len();
                    self.bcast_pages_got = pages.len();
                    for p in &pages {
                        self.pages_seen.insert(p.flood_key().expect("page key"));
                        out.broadcast(graph.neighbors(self.id), p);
                    }
                    self.centers_got = true;
                    out.broadcast(
                        graph.neighbors(self.id),
                        &Payload::Centers(Arc::new(sol.centers.clone())),
                    );
                    self.trace_phase(Phase::Broadcast, true);
                }
                PipeRole::Graph { .. } => {}
            }
            self.solution = Some(sol);
            self.finished = Some(coreset);
        }
    }
}

/// Fold one portion page into a node's sketch (free function so match
/// arms holding a borrow of `role` can still fold). Duplicate
/// deliveries (the sketch's tracker rejects them) are not counted, so
/// count-based completion stays correct under any retransmitting
/// delivery layer. A node without a fold (graph forwarder whose sketch
/// was elided) still counts the page — its caller already deduped it —
/// so the driver's everyone-saw-everything check keeps working.
fn fold_page(fold: &mut Option<Sketch<'_>>, pages_folded: &mut usize, p: &Payload) {
    if let Payload::PortionPage {
        site,
        page,
        pages,
        set,
    } = p
    {
        match fold.as_mut() {
            Some(f) => {
                if f.insert_page(*site, *page, *pages, set) {
                    *pages_folded += 1;
                }
            }
            None => *pages_folded += 1,
        }
    } else {
        unreachable!("fold_page on non-page payload");
    }
}

impl NodeMachine for PipeMachine<'_> {
    fn tick(&mut self, out: &mut Outbox) {
        if self.failed {
            return;
        }
        // First tick: emit the own cost scalar.
        if let Some(c) = self.cost.take() {
            match &self.role {
                PipeRole::Graph { graph } | PipeRole::Overlay { graph, .. } => {
                    self.costs_seen.insert(c.flood_key().expect("cost key"));
                    out.broadcast(graph.neighbors(self.id), &c);
                }
                PipeRole::Tree { parent, .. } => {
                    if parent.is_none() {
                        self.costs_seen.insert(c.flood_key().expect("cost key"));
                    } else {
                        self.relay_points += c.size_points();
                        self.relay_up.push(c);
                    }
                }
            }
        }
        // Cost phase completion.
        if !self.ready && self.costs_expected > 0 && self.costs_seen.len() == self.costs_expected
        {
            self.ready = true;
            self.trace_ready_flip();
            // Tree root: answer with the budget total.
            if let (PipeRole::Tree { children, .. }, Some(t)) = (&self.role, self.total.take())
            {
                for &c in children {
                    out.send(c, t.clone());
                }
            }
        }
        // Page streaming starts as soon as this node is ready.
        if self.ready && !self.launched {
            self.launch(out);
        }
        // Completion: reduce-and-forward, or solve-and-broadcast.
        if self.launched && !self.done && self.collection_complete() {
            self.done = true;
            self.trace_phase(Phase::ConvergeFold, false);
            self.on_complete(out);
        }
        // Tree: move relayed payloads one hop up.
        if let PipeRole::Tree {
            parent: Some(parent),
            ..
        } = self.role
        {
            for p in self.relay_up.drain(..) {
                out.send(parent, p);
            }
            self.relay_points = 0;
        }
    }

    fn on_msg(&mut self, _from: usize, msg: Payload, out: &mut Outbox) {
        if self.failed {
            return;
        }
        match (&self.role, msg) {
            (PipeRole::Graph { graph }, msg @ Payload::LocalCost { .. }) => {
                let key = msg.flood_key().expect("cost key");
                if self.costs_seen.insert(key) {
                    out.broadcast(graph.neighbors(self.id), &msg);
                }
            }
            (PipeRole::Graph { graph }, msg @ Payload::PortionPage { .. }) => {
                let key = msg.flood_key().expect("page key");
                if self.pages_seen.insert(key) {
                    out.broadcast(graph.neighbors(self.id), &msg);
                    fold_page(&mut self.fold, &mut self.pages_folded, &msg);
                }
            }
            (PipeRole::Tree { parent, .. }, msg @ Payload::LocalCost { .. }) => {
                if parent.is_none() {
                    self.costs_seen
                        .insert(msg.flood_key().expect("cost key"));
                } else {
                    self.relay_points += msg.size_points();
                    self.relay_up.push(msg);
                }
            }
            (PipeRole::Tree { .. }, msg @ Payload::PortionPage { .. }) => {
                if self.fold.is_some() {
                    // Folding node (root, or reducing relay).
                    fold_page(&mut self.fold, &mut self.pages_folded, &msg);
                } else {
                    self.relay_points += msg.size_points();
                    self.relay_up.push(msg);
                }
            }
            (PipeRole::Tree { children, .. }, msg @ Payload::Scalar(_)) => {
                if !self.ready {
                    self.ready = true;
                    if let Some(t) = &self.tracer {
                        t.phase(self.id, Phase::CostFlood, false);
                        t.phase(self.id, Phase::ConvergeFold, true);
                    }
                }
                for &c in children {
                    out.send(c, msg.clone());
                }
            }
            (PipeRole::Tree { children, .. }, msg @ Payload::Centers(_)) => {
                if let Some(t) = &self.tracer {
                    t.phase(self.id, Phase::Broadcast, false);
                }
                for &c in children {
                    out.send(c, msg.clone());
                }
            }
            (PipeRole::Overlay { graph, .. }, msg @ Payload::LocalCost { .. }) => {
                let key = msg.flood_key().expect("cost key");
                if self.costs_seen.insert(key) {
                    out.broadcast(graph.neighbors(self.id), &msg);
                }
            }
            (PipeRole::Overlay { graph, .. }, msg @ Payload::PortionPage { .. }) => {
                if !self.done {
                    // Converge phase: an overlay child's reduced stream.
                    // (The root completes only after every node's subtree
                    // did, so a reduced-set flood page can never arrive
                    // before this node finished its own fold.)
                    fold_page(&mut self.fold, &mut self.pages_folded, &msg);
                } else {
                    // The root's reduced set flooding back over the graph.
                    let key = msg.flood_key().expect("page key");
                    if self.pages_seen.insert(key) {
                        if let Payload::PortionPage { pages, .. } = &msg {
                            self.bcast_pages_total = *pages as usize;
                        }
                        self.bcast_pages_got += 1;
                        out.broadcast(graph.neighbors(self.id), &msg);
                    }
                }
            }
            (PipeRole::Overlay { graph, .. }, msg @ Payload::Centers(_)) => {
                // Single in-flight payload: a boolean is its flood dedup.
                if !self.centers_got {
                    self.centers_got = true;
                    if let Some(t) = &self.tracer {
                        t.phase(self.id, Phase::Broadcast, false);
                    }
                    out.broadcast(graph.neighbors(self.id), &msg);
                }
            }
            (_, other) => unreachable!("pipeline: unexpected payload {other:?}"),
        }
        self.bump_peak();
    }

    fn wants_tick(&self) -> bool {
        // Mirrors every action `tick` can take without a new delivery:
        // cost emission, cost-phase completion, page launch, collection
        // completion, relay drain. Anything else only becomes actionable
        // through `on_msg`, after which the node is scheduled anyway.
        // Failed nodes act on nothing.
        !self.failed
            && (self.cost.is_some()
                || !self.relay_up.is_empty()
                || (!self.ready
                    && self.costs_expected > 0
                    && self.costs_seen.len() == self.costs_expected)
                || (self.ready && !self.launched)
                || (self.launched && !self.done && self.collection_complete()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::generators;

    #[test]
    fn drive_terminates_on_silent_machines() {
        struct Quiet;
        impl NodeMachine for Quiet {
            fn tick(&mut self, _out: &mut Outbox) {}
            fn on_msg(&mut self, _from: usize, _msg: Payload, _out: &mut Outbox) {}
        }
        let mut net = Network::new(generators::path(3));
        let mut nodes = vec![Quiet, Quiet, Quiet];
        let stats = drive(&mut net, &mut nodes);
        assert_eq!(net.cost_points(), 0);
        assert_eq!(net.round(), 1, "one empty round detects quiescence");
        assert_eq!(stats, DriveStats { node_ticks: 3, rounds: 1 });
    }

    #[test]
    fn flood_machines_deliver_and_meter_like_algorithm_3() {
        let g = generators::grid(3, 3);
        let (n, m) = (g.n(), g.m());
        let mut net = Network::new(g);
        let shared = net.graph_shared();
        let mut nodes: Vec<FloodMachine> = (0..n)
            .map(|i| {
                FloodMachine::new(
                    Arc::clone(&shared),
                    i,
                    vec![Payload::LocalCost {
                        site: i,
                        cost: i as f64,
                    }],
                )
            })
            .collect();
        let stats = drive(&mut net, &mut nodes);
        for node in &nodes {
            assert_eq!(node.held.len(), n);
        }
        assert_eq!(net.cost_points(), 2 * m * n);
        // The active-set loop never schedules more work than dense
        // (n × rounds) would.
        assert!(stats.node_ticks <= (n as u64) * stats.rounds);
    }

    #[test]
    fn reparent_path_re_merges_only_the_surviving_subtree() {
        // Diamond graph 0-1, 0-2, 1-3, 2-3; tree 0 → {1, 2}, 1 → {3}.
        // Relay 1 fails before the drive; its orphan 3 is re-parented to
        // the surviving neighbor 2 (a graph edge), the root stops
        // waiting for 1, and the re-merge completes with 1's own portion
        // lost — all through the ordinary session drive loop.
        use crate::points::{Dataset, WeightedSet};
        use crate::sketch::ExactSketch;

        let g = Graph::from_edges(4, &[(0, 1), (0, 2), (1, 3), (2, 3)]);
        let old_children: [&[usize]; 4] = [&[1, 2], &[3], &[], &[]];
        let old_parent = [None, Some(0), Some(0), Some(1)];
        let mut net = Network::new(g);
        let mut nodes: Vec<PipeMachine> = (0..4)
            .map(|v| {
                let portion =
                    WeightedSet::unit(Dataset::from_flat(vec![v as f32], 1));
                PipeMachine::tree(
                    v,
                    old_parent[v],
                    old_children[v].to_vec(),
                    None,
                    None,
                    paginate(v, Arc::new(portion), 4),
                    4,
                    Some(Sketch::Exact(ExactSketch::new())),
                    usize::MAX,
                    1 + old_children[v].len(),
                    old_parent[v].is_some(),
                    4,
                    None,
                )
            })
            .collect();
        nodes[1].fail();
        nodes[3].reparent(Some(2));
        nodes[2].adopt_child(3);
        nodes[0].drop_child(1);
        drive(&mut net, &mut nodes);
        let merged = nodes[0].take_fold().expect("root keeps its fold").finish().unwrap();
        // Exact folds reproduce site order: root's own portion (site 0),
        // then node 2's reduced stream (site 2 = its portion + orphan 3).
        assert_eq!(merged.points.data, vec![0.0, 2.0, 3.0]);
        // Wire bill: orphan 3's one point to its new parent, plus the
        // two-point reduced stream 2 → 0. The failed relay's portion
        // never moves.
        assert_eq!(net.cost_points(), 3);
    }

    #[test]
    fn zhang_machines_pipeline_tree_levels() {
        // A star rooted at the hub: every leaf's summary moves in the
        // same round, so the whole converge-cast takes O(1) rounds
        // instead of one synchronous step per edge.
        let g = generators::star(9);
        let tree = crate::topology::SpanningTree::bfs(&g, 0);
        let mut net = Network::new(tree.as_graph()).without_transcript();
        let mut nodes: Vec<ZhangMachine> = (0..9)
            .map(|v| {
                let is_root = v == tree.root;
                ZhangMachine::new(
                    (!is_root).then_some(tree.parent[v]),
                    tree.children[v].len(),
                    (!is_root).then_some(Payload::Opaque { site: v, points: 10 }),
                )
            })
            .collect();
        drive(&mut net, &mut nodes);
        assert_eq!(net.cost_points(), 8 * 10);
        assert!(
            net.round() <= 3,
            "star converge-cast must pipeline: {} rounds",
            net.round()
        );
    }
}
