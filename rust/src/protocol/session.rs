//! The unified protocol engine: per-node state machines driven by one
//! synchronous round loop.
//!
//! Every protocol primitive (flooding, converge-cast, broadcast) and the
//! end-to-end clustering pipeline are expressed as [`NodeMachine`]s: a
//! machine reacts to delivered messages and to the start of each round,
//! and queues sends through an [`Outbox`]. [`drive`] owns the loop —
//! tick every node, advance the simulator one round, deliver — so
//! *phases overlap naturally*: a site whose inputs arrived early starts
//! its next phase while slower parts of the network are still busy
//! (e.g. Round-2 portion pages enter the network while the Round-1 cost
//! flood is still propagating elsewhere), and a capacity-limited
//! [`LinkModel`](crate::network::LinkModel) back-pressures everything
//! without any machine having to know about it.
//!
//! All machine logic runs on the driver thread and is a pure function of
//! the message history, so `rounds`, `cost_points` and `peak_points` are
//! bit-identical for any worker-thread count of the compute layer.

use crate::network::{FloodKey, Network, Payload};
use std::collections::HashSet;

/// Sends queued by a machine during one callback: `(to, payload)`.
#[derive(Default)]
pub(crate) struct Outbox {
    pub(crate) sends: Vec<(usize, Payload)>,
}

impl Outbox {
    /// Queue one send.
    pub(crate) fn send(&mut self, to: usize, payload: Payload) {
        self.sends.push((to, payload));
    }

    /// Queue a clone per neighbor (payloads are `Arc`-backed: O(1) each).
    pub(crate) fn broadcast(&mut self, neigh: &[usize], payload: &Payload) {
        for &to in neigh {
            self.sends.push((to, payload.clone()));
        }
    }
}

/// One node's protocol logic.
pub(crate) trait NodeMachine {
    /// Start-of-round hook. First invocation doubles as initialization
    /// (machines drain their origin payloads then); later invocations
    /// flush whatever earlier deliveries made sendable.
    fn tick(&mut self, out: &mut Outbox);

    /// One message delivered to this node in the round just stepped.
    fn on_msg(&mut self, from: usize, msg: Payload, out: &mut Outbox);
}

/// Run machines to quiescence: tick all nodes, advance one synchronous
/// round, deliver. Terminates when a round moves no messages — by then
/// no machine has pending sends (ticks already ran) and the simulator is
/// drained.
pub(crate) fn drive<M: NodeMachine>(net: &mut Network, nodes: &mut [M]) {
    let n = nodes.len();
    assert_eq!(net.n(), n, "one machine per node");
    loop {
        for v in 0..n {
            let mut out = Outbox::default();
            nodes[v].tick(&mut out);
            for (to, p) in out.sends {
                net.send(v, to, p);
            }
        }
        if net.step() == 0 {
            break;
        }
        for v in 0..n {
            for (from, p) in net.recv_all(v) {
                let mut out = Outbox::default();
                nodes[v].on_msg(from, p, &mut out);
                for (to, q) in out.sends {
                    net.send(v, to, q);
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// Primitive machines
// ---------------------------------------------------------------------

/// Algorithm 3 flooding: originate payloads, forward each distinct key
/// to every neighbor exactly once.
pub(crate) struct FloodMachine {
    neigh: Vec<usize>,
    origin: Vec<Payload>,
    seen: HashSet<FloodKey>,
    /// Every payload this node ended up holding (its own included).
    pub(crate) held: Vec<Payload>,
}

impl FloodMachine {
    pub(crate) fn new(neigh: Vec<usize>, origin: Vec<Payload>) -> Self {
        FloodMachine {
            neigh,
            origin,
            seen: HashSet::new(),
            held: Vec::new(),
        }
    }
}

impl NodeMachine for FloodMachine {
    fn tick(&mut self, out: &mut Outbox) {
        for p in self.origin.drain(..) {
            let key = p.flood_key().expect("flooded payloads must have an origin");
            self.seen.insert(key);
            out.broadcast(&self.neigh, &p);
            self.held.push(p);
        }
    }

    fn on_msg(&mut self, _from: usize, msg: Payload, out: &mut Outbox) {
        let key = msg.flood_key().expect("floodable");
        if self.seen.insert(key) {
            out.broadcast(&self.neigh, &msg);
            self.held.push(msg);
        }
    }
}

/// Theorem 3 converge-cast: relay every payload one hop toward the root
/// per round.
pub(crate) struct ConvergeMachine {
    /// `None` at the root.
    parent: Option<usize>,
    relay: Vec<Payload>,
    /// Root only: everything that arrived (its own payloads included).
    pub(crate) collected: Vec<Payload>,
}

impl ConvergeMachine {
    pub(crate) fn new(parent: Option<usize>, own: Vec<Payload>) -> Self {
        let (relay, collected) = if parent.is_some() {
            (own, Vec::new())
        } else {
            (Vec::new(), own)
        };
        ConvergeMachine {
            parent,
            relay,
            collected,
        }
    }
}

impl NodeMachine for ConvergeMachine {
    fn tick(&mut self, out: &mut Outbox) {
        if let Some(parent) = self.parent {
            for p in self.relay.drain(..) {
                out.send(parent, p);
            }
        }
    }

    fn on_msg(&mut self, _from: usize, msg: Payload, _out: &mut Outbox) {
        if self.parent.is_none() {
            self.collected.push(msg);
        } else {
            self.relay.push(msg);
        }
    }
}

/// Root-to-leaves broadcast: each tree edge carries the payload once.
pub(crate) struct BroadcastMachine {
    children: Vec<usize>,
    /// Root's payload, emitted on the first tick.
    origin: Option<Payload>,
    /// Set once the payload reached this node (true at the root).
    pub(crate) received: bool,
}

impl BroadcastMachine {
    pub(crate) fn new(children: Vec<usize>, origin: Option<Payload>) -> Self {
        let received = origin.is_some();
        BroadcastMachine {
            children,
            origin,
            received,
        }
    }
}

impl NodeMachine for BroadcastMachine {
    fn tick(&mut self, out: &mut Outbox) {
        if let Some(p) = self.origin.take() {
            for &c in &self.children {
                out.send(c, p.clone());
            }
        }
    }

    fn on_msg(&mut self, _from: usize, msg: Payload, out: &mut Outbox) {
        self.received = true;
        for &c in &self.children {
            out.send(c, msg.clone());
        }
    }
}

// ---------------------------------------------------------------------
// End-to-end pipeline machine (Algorithm 2 over either topology)
// ---------------------------------------------------------------------

/// How a pipeline node is wired into the topology.
pub(crate) enum PipeRole {
    /// General graph: flood everything to everyone.
    Graph {
        /// Neighbor list.
        neigh: Vec<usize>,
    },
    /// Rooted spanning tree: converge up, broadcast down.
    Tree {
        /// `None` at the root.
        parent: Option<usize>,
        /// Children, ascending node id.
        children: Vec<usize>,
    },
}

/// Per-node state machine of the unified clustering pipeline.
///
/// Phases per node — each entered as soon as *this node's* inputs are
/// complete, regardless of global progress:
///
/// 1. cost exchange (optional; the paper's Round 1 scalar): graph nodes
///    flood their `LocalCost`, tree nodes relay costs to the root, which
///    answers with the `Scalar` total;
/// 2. portion streaming: once *ready* (all costs seen on a graph / total
///    received on a tree / immediately when the plan needs no cost
///    exchange), the node emits its portion pages — overlapping with
///    cost traffic still propagating elsewhere;
/// 3. solution broadcast (tree only): when the root holds every page it
///    broadcasts the precomputed `Centers` down.
pub(crate) struct PipeMachine {
    role: PipeRole,
    /// Own `LocalCost`, emitted on the first tick (None: no cost phase).
    cost: Option<Payload>,
    /// Distinct cost keys seen (graph: dedup+count; tree root: count).
    costs_seen: HashSet<FloodKey>,
    /// Cost keys required before this node/root proceeds (0 = no cost
    /// phase).
    costs_expected: usize,
    /// Tree: payloads waiting to move one hop toward the root.
    relay_up: Vec<Payload>,
    /// Tree root: `Scalar` budget total, broadcast when costs complete.
    total: Option<Payload>,
    /// This node may emit its own pages.
    ready: bool,
    launched: bool,
    /// Own portion pages.
    pages: Vec<Payload>,
    /// Graph: distinct page keys seen (flooding dedup).
    pages_seen: HashSet<FloodKey>,
    /// Collected pages (every node on a graph; the root on a tree).
    pub(crate) held: Vec<Payload>,
    /// Pages that complete the collection (`usize::MAX`: not a
    /// collector).
    pages_expected: usize,
    /// Tree root: precomputed final solution, broadcast when all pages
    /// arrived.
    centers: Option<Payload>,
}

impl PipeMachine {
    /// Graph-mode node. `cost` is `None` for plans without a cost
    /// exchange (then the node is ready immediately).
    pub(crate) fn graph(
        neigh: Vec<usize>,
        cost: Option<Payload>,
        pages: Vec<Payload>,
        n_nodes: usize,
        pages_expected: usize,
    ) -> Self {
        let has_cost = cost.is_some();
        PipeMachine {
            role: PipeRole::Graph { neigh },
            cost,
            costs_seen: HashSet::new(),
            costs_expected: if has_cost { n_nodes } else { 0 },
            relay_up: Vec::new(),
            total: None,
            ready: !has_cost,
            launched: false,
            pages,
            pages_seen: HashSet::new(),
            held: Vec::new(),
            pages_expected,
            centers: None,
        }
    }

    /// Tree-mode node. Only the root takes `total`/`centers` and a
    /// nonzero `costs_expected`/finite `pages_expected`.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn tree(
        parent: Option<usize>,
        children: Vec<usize>,
        cost: Option<Payload>,
        total: Option<Payload>,
        pages: Vec<Payload>,
        pages_expected: usize,
        n_nodes: usize,
        centers: Option<Payload>,
    ) -> Self {
        let has_cost = cost.is_some();
        let is_root = parent.is_none();
        PipeMachine {
            role: PipeRole::Tree { parent, children },
            cost,
            costs_seen: HashSet::new(),
            costs_expected: if has_cost && is_root { n_nodes } else { 0 },
            relay_up: Vec::new(),
            total,
            // Roots without a cost phase are ready at once; non-roots
            // without a cost phase likewise. With a cost phase everyone
            // waits (the root for the full count, others for the total).
            ready: !has_cost,
            launched: false,
            pages,
            pages_seen: HashSet::new(),
            held: Vec::new(),
            pages_expected,
            centers,
        }
    }

    fn launch(&mut self, out: &mut Outbox) {
        self.launched = true;
        match &self.role {
            PipeRole::Graph { neigh } => {
                for p in std::mem::take(&mut self.pages) {
                    self.pages_seen
                        .insert(p.flood_key().expect("page key"));
                    out.broadcast(neigh, &p);
                    self.held.push(p);
                }
            }
            PipeRole::Tree { parent, .. } => {
                if parent.is_none() {
                    // The root keeps its own pages; nothing to send.
                    self.held.append(&mut self.pages);
                } else {
                    self.relay_up.append(&mut self.pages);
                }
            }
        }
    }
}

impl NodeMachine for PipeMachine {
    fn tick(&mut self, out: &mut Outbox) {
        // First tick: emit the own cost scalar.
        if let Some(c) = self.cost.take() {
            match &self.role {
                PipeRole::Graph { neigh } => {
                    self.costs_seen.insert(c.flood_key().expect("cost key"));
                    out.broadcast(neigh, &c);
                }
                PipeRole::Tree { parent, .. } => {
                    if parent.is_none() {
                        self.costs_seen.insert(c.flood_key().expect("cost key"));
                    } else {
                        self.relay_up.push(c);
                    }
                }
            }
        }
        // Cost phase completion.
        if !self.ready && self.costs_expected > 0 && self.costs_seen.len() == self.costs_expected
        {
            self.ready = true;
            // Tree root: answer with the budget total.
            if let (PipeRole::Tree { children, .. }, Some(t)) = (&self.role, self.total.take())
            {
                for &c in children {
                    out.send(c, t.clone());
                }
            }
        }
        // Page streaming starts as soon as this node is ready.
        if self.ready && !self.launched {
            self.launch(out);
        }
        // Tree root: final solution once every page arrived.
        if self.launched && self.held.len() == self.pages_expected {
            if let (PipeRole::Tree { children, .. }, Some(c)) = (&self.role, self.centers.take())
            {
                for &child in children {
                    out.send(child, c.clone());
                }
            }
        }
        // Tree: move relayed payloads one hop up.
        if let PipeRole::Tree {
            parent: Some(parent),
            ..
        } = self.role
        {
            for p in self.relay_up.drain(..) {
                out.send(parent, p);
            }
        }
    }

    fn on_msg(&mut self, _from: usize, msg: Payload, out: &mut Outbox) {
        match (&self.role, msg) {
            (PipeRole::Graph { neigh }, msg @ Payload::LocalCost { .. }) => {
                let key = msg.flood_key().expect("cost key");
                if self.costs_seen.insert(key) {
                    out.broadcast(neigh, &msg);
                }
            }
            (PipeRole::Graph { neigh }, msg @ Payload::PortionPage { .. }) => {
                let key = msg.flood_key().expect("page key");
                if self.pages_seen.insert(key) {
                    out.broadcast(neigh, &msg);
                    self.held.push(msg);
                }
            }
            (PipeRole::Tree { parent, .. }, msg @ Payload::LocalCost { .. }) => {
                if parent.is_none() {
                    self.costs_seen
                        .insert(msg.flood_key().expect("cost key"));
                } else {
                    self.relay_up.push(msg);
                }
            }
            (PipeRole::Tree { parent, .. }, msg @ Payload::PortionPage { .. }) => {
                if parent.is_none() {
                    self.held.push(msg);
                } else {
                    self.relay_up.push(msg);
                }
            }
            (PipeRole::Tree { children, .. }, msg @ Payload::Scalar(_)) => {
                self.ready = true;
                for &c in children {
                    out.send(c, msg.clone());
                }
            }
            (PipeRole::Tree { children, .. }, msg @ Payload::Centers(_)) => {
                for &c in children {
                    out.send(c, msg.clone());
                }
            }
            (_, other) => unreachable!("pipeline: unexpected payload {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::generators;

    #[test]
    fn drive_terminates_on_silent_machines() {
        struct Quiet;
        impl NodeMachine for Quiet {
            fn tick(&mut self, _out: &mut Outbox) {}
            fn on_msg(&mut self, _from: usize, _msg: Payload, _out: &mut Outbox) {}
        }
        let mut net = Network::new(generators::path(3));
        let mut nodes = vec![Quiet, Quiet, Quiet];
        drive(&mut net, &mut nodes);
        assert_eq!(net.cost_points(), 0);
        assert_eq!(net.round(), 1, "one empty round detects quiescence");
    }

    #[test]
    fn flood_machines_deliver_and_meter_like_algorithm_3() {
        let g = generators::grid(3, 3);
        let (n, m) = (g.n(), g.m());
        let mut net = Network::new(g.clone());
        let mut nodes: Vec<FloodMachine> = (0..n)
            .map(|i| {
                FloodMachine::new(
                    g.neighbors(i).to_vec(),
                    vec![Payload::LocalCost {
                        site: i,
                        cost: i as f64,
                    }],
                )
            })
            .collect();
        drive(&mut net, &mut nodes);
        for node in &nodes {
            assert_eq!(node.held.len(), n);
        }
        assert_eq!(net.cost_points(), 2 * m * n);
    }
}
