//! **Algorithm 2** — end-to-end distributed clustering drivers.
//!
//! Variants: the paper's algorithm over general graphs (flooding) and
//! over rooted trees (converge-cast), plus the two baselines wired
//! through the same network simulator so every figure compares *measured*
//! communication, not assumed bounds.

use crate::clustering::backend::Backend;
use crate::clustering::{approx_solution, Solution};
use crate::coreset::combine::{self, CombineConfig};
use crate::coreset::distributed::{self, allocate_budget, local_cost, DistributedConfig};
use crate::coreset::zhang::{self, ZhangConfig};
use crate::coreset::Coreset;
use crate::exec::{map_sites, ExecPolicy};
use crate::network::{Network, Payload};
use crate::points::{Dataset, WeightedSet};
use crate::protocol::{broadcast_down, converge_cast, flood};
use crate::rng::Pcg64;
use crate::topology::{Graph, SpanningTree};

/// Outcome of one distributed clustering run.
#[derive(Clone, Debug)]
pub struct RunResult {
    /// The k centers of the final solution.
    pub centers: Dataset,
    /// Cost of the solution *on the coreset* (the solver's view).
    pub coreset_cost: f64,
    /// The global coreset the solution was computed on.
    pub coreset: Coreset,
    /// Total measured communication (points transmitted).
    pub comm_points: usize,
    /// Synchronous network rounds used.
    pub rounds: usize,
    /// Algorithm label for reports.
    pub algorithm: &'static str,
}

fn solve_on(
    coreset: &Coreset,
    k: usize,
    cfg_obj: crate::clustering::Objective,
    backend: &dyn Backend,
    rng: &mut Pcg64,
) -> Solution {
    approx_solution(&coreset.set, k, cfg_obj, backend, rng, 40)
}

/// The paper's algorithm on a general graph: distributed coreset
/// construction with flooding for both the cost exchange and the coreset
/// exchange. Every node ends holding the full coreset (as in Algorithm
/// 2); the solver runs once since all nodes compute identically.
///
/// Sequential legacy entry point — see [`cluster_on_graph_exec`] for
/// the parallel execution engine.
pub fn cluster_on_graph(
    graph: &Graph,
    locals: &[WeightedSet],
    cfg: &DistributedConfig,
    backend: &dyn Backend,
    rng: &mut Pcg64,
) -> anyhow::Result<RunResult> {
    cluster_on_graph_exec(graph, locals, cfg, backend, rng, ExecPolicy::Sequential)
}

/// [`cluster_on_graph`] under an explicit [`ExecPolicy`]: Round 1 and
/// Round 2 run per-site on worker threads (the network simulation — a
/// bookkeeping pass — stays on the caller's thread). Results are
/// independent of the thread count; see [`crate::exec`].
pub fn cluster_on_graph_exec(
    graph: &Graph,
    locals: &[WeightedSet],
    cfg: &DistributedConfig,
    backend: &dyn Backend,
    rng: &mut Pcg64,
    exec: ExecPolicy,
) -> anyhow::Result<RunResult> {
    anyhow::ensure!(graph.n() == locals.len(), "one local set per node");
    let mut net = Network::new(graph.clone()).without_transcript();

    // Round 1: local solves; flood the scalar costs.
    let summaries: Vec<_> = map_sites(locals.len(), rng, exec, |i, r| {
        distributed::round1(&locals[i], cfg, backend, r)
    });
    let cost_payloads: Vec<Payload> = summaries
        .iter()
        .enumerate()
        .map(|(i, s)| Payload::LocalCost {
            site: i,
            cost: local_cost(s, cfg.objective),
        })
        .collect();
    let held = flood(&mut net, cost_payloads);

    // Every node now knows every cost; reconstruct (identically) at node 0.
    let costs: Vec<f64> = held[0]
        .iter()
        .map(|p| match p {
            Payload::LocalCost { cost, .. } => *cost,
            _ => unreachable!(),
        })
        .collect();
    let total: f64 = costs.iter().sum();
    let budgets = allocate_budget(cfg.t, &costs);

    // Round 2: local portions; flood them so all nodes hold the coreset.
    let portions: Vec<Coreset> = map_sites(locals.len(), rng, exec, |i, r| {
        distributed::round2(&locals[i], &summaries[i], cfg, budgets[i], total, r)
    });
    let portion_payloads: Vec<Payload> = portions
        .iter()
        .enumerate()
        .map(|(i, c)| Payload::Portion {
            site: i,
            set: std::sync::Arc::new(c.set.clone()),
        })
        .collect();
    flood(&mut net, portion_payloads);

    let coreset = distributed::union(&portions);
    let sol = solve_on(&coreset, cfg.k, cfg.objective, backend, rng);
    Ok(RunResult {
        centers: sol.centers,
        coreset_cost: sol.cost,
        coreset,
        comm_points: net.cost_points(),
        rounds: net.round(),
        algorithm: "distributed-coreset (Alg.1+3)",
    })
}

/// The paper's algorithm on a rooted tree (Theorem 3): costs converge to
/// the root, the total broadcasts down, portions converge to the root,
/// the root solves and broadcasts the centers.
///
/// Sequential legacy entry point — see [`cluster_on_tree_exec`] for the
/// parallel execution engine.
pub fn cluster_on_tree(
    tree: &SpanningTree,
    locals: &[WeightedSet],
    cfg: &DistributedConfig,
    backend: &dyn Backend,
    rng: &mut Pcg64,
) -> anyhow::Result<RunResult> {
    cluster_on_tree_exec(tree, locals, cfg, backend, rng, ExecPolicy::Sequential)
}

/// [`cluster_on_tree`] under an explicit [`ExecPolicy`] (same contract
/// as [`cluster_on_graph_exec`]).
pub fn cluster_on_tree_exec(
    tree: &SpanningTree,
    locals: &[WeightedSet],
    cfg: &DistributedConfig,
    backend: &dyn Backend,
    rng: &mut Pcg64,
    exec: ExecPolicy,
) -> anyhow::Result<RunResult> {
    anyhow::ensure!(tree.n() == locals.len(), "one local set per node");
    let mut net = Network::new(tree.as_graph()).without_transcript();

    let summaries: Vec<_> = map_sites(locals.len(), rng, exec, |i, r| {
        distributed::round1(&locals[i], cfg, backend, r)
    });
    let cost_payloads: Vec<Payload> = summaries
        .iter()
        .enumerate()
        .map(|(i, s)| Payload::LocalCost {
            site: i,
            cost: local_cost(s, cfg.objective),
        })
        .collect();
    let at_root = converge_cast(&mut net, tree, cost_payloads);
    let costs: Vec<f64> = at_root
        .iter()
        .map(|p| match p {
            Payload::LocalCost { cost, .. } => *cost,
            _ => unreachable!(),
        })
        .collect();
    let total: f64 = costs.iter().sum();
    broadcast_down(&mut net, tree, &Payload::Scalar(total));

    let budgets = allocate_budget(cfg.t, &costs);
    let portions: Vec<Coreset> = map_sites(locals.len(), rng, exec, |i, r| {
        distributed::round2(&locals[i], &summaries[i], cfg, budgets[i], total, r)
    });
    let portion_payloads: Vec<Payload> = portions
        .iter()
        .enumerate()
        .map(|(i, c)| Payload::Portion {
            site: i,
            set: std::sync::Arc::new(c.set.clone()),
        })
        .collect();
    converge_cast(&mut net, tree, portion_payloads);

    let coreset = distributed::union(&portions);
    let sol = solve_on(&coreset, cfg.k, cfg.objective, backend, rng);
    broadcast_down(&mut net, tree, &Payload::Centers(sol.centers.clone()));
    Ok(RunResult {
        centers: sol.centers,
        coreset_cost: sol.cost,
        coreset,
        comm_points: net.cost_points(),
        rounds: net.round(),
        algorithm: "distributed-coreset (tree)",
    })
}

/// COMBINE baseline on a general graph: local FL11 coresets flooded to
/// every node.
pub fn combine_on_graph(
    graph: &Graph,
    locals: &[WeightedSet],
    cfg: &CombineConfig,
    backend: &dyn Backend,
    rng: &mut Pcg64,
) -> anyhow::Result<RunResult> {
    anyhow::ensure!(graph.n() == locals.len());
    let mut net = Network::new(graph.clone()).without_transcript();
    let portions = combine::build_portions(locals, cfg, backend, rng);
    let payloads: Vec<Payload> = portions
        .iter()
        .enumerate()
        .map(|(i, c)| Payload::Portion {
            site: i,
            set: std::sync::Arc::new(c.set.clone()),
        })
        .collect();
    flood(&mut net, payloads);
    let coreset = distributed::union(&portions);
    let sol = solve_on(&coreset, cfg.k, cfg.objective, backend, rng);
    Ok(RunResult {
        centers: sol.centers,
        coreset_cost: sol.cost,
        coreset,
        comm_points: net.cost_points(),
        rounds: net.round(),
        algorithm: "combine",
    })
}

/// COMBINE baseline on a rooted tree: local coresets converge to the
/// root, which solves and broadcasts.
pub fn combine_on_tree(
    tree: &SpanningTree,
    locals: &[WeightedSet],
    cfg: &CombineConfig,
    backend: &dyn Backend,
    rng: &mut Pcg64,
) -> anyhow::Result<RunResult> {
    anyhow::ensure!(tree.n() == locals.len());
    let mut net = Network::new(tree.as_graph()).without_transcript();
    let portions = combine::build_portions(locals, cfg, backend, rng);
    let payloads: Vec<Payload> = portions
        .iter()
        .enumerate()
        .map(|(i, c)| Payload::Portion {
            site: i,
            set: std::sync::Arc::new(c.set.clone()),
        })
        .collect();
    converge_cast(&mut net, tree, payloads);
    let coreset = distributed::union(&portions);
    let sol = solve_on(&coreset, cfg.k, cfg.objective, backend, rng);
    broadcast_down(&mut net, tree, &Payload::Centers(sol.centers.clone()));
    Ok(RunResult {
        centers: sol.centers,
        coreset_cost: sol.cost,
        coreset,
        comm_points: net.cost_points(),
        rounds: net.round(),
        algorithm: "combine (tree)",
    })
}

/// Zhang-et-al. baseline on a rooted tree: coreset-of-coresets composed
/// bottom-up, each hop charged through the simulator.
pub fn zhang_on_tree(
    tree: &SpanningTree,
    locals: &[WeightedSet],
    cfg: &ZhangConfig,
    backend: &dyn Backend,
    rng: &mut Pcg64,
) -> anyhow::Result<RunResult> {
    anyhow::ensure!(tree.n() == locals.len());
    let mut net = Network::new(tree.as_graph()).without_transcript();
    let result = zhang::build_on_tree(locals, tree, cfg, backend, rng);
    // Charge each child -> parent summary transfer on the simulator.
    for v in 0..tree.n() {
        if v != tree.root && result.sent_points[v] > 0 {
            let set = WeightedSet::new(
                Dataset::from_flat(
                    vec![0.0; result.sent_points[v] * locals[v].d().max(1)],
                    locals[v].d().max(1),
                ),
                vec![0.0; result.sent_points[v]],
            );
            net.send(v, tree.parent[v], Payload::Portion { site: v, set: std::sync::Arc::new(set) });
            net.step();
            net.recv_all(tree.parent[v]);
        }
    }
    let sol = solve_on(&result.coreset, cfg.k, cfg.objective, backend, rng);
    broadcast_down(&mut net, tree, &Payload::Centers(sol.centers.clone()));
    Ok(RunResult {
        centers: sol.centers,
        coreset_cost: sol.cost,
        coreset: result.coreset,
        comm_points: net.cost_points(),
        rounds: net.round(),
        algorithm: "zhang (tree)",
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clustering::backend::RustBackend;
    use crate::clustering::{cost_of, Objective};
    use crate::data::synthetic::gaussian_mixture;
    use crate::partition::Scheme;
    use crate::topology::generators;

    fn setup(seed: u64, sites: usize) -> (Graph, Vec<WeightedSet>, WeightedSet) {
        let mut rng = Pcg64::seed_from(seed);
        let data = gaussian_mixture(&mut rng, 4_000, 5, 4);
        let g = generators::erdos_renyi_connected(&mut rng, sites, 0.3);
        let locals: Vec<WeightedSet> = Scheme::Weighted
            .partition_on(&data, &g, &mut rng)
            .into_iter()
            .map(WeightedSet::unit)
            .collect();
        let global = WeightedSet::union(locals.iter());
        (g, locals, global)
    }

    #[test]
    fn graph_run_produces_good_solution() {
        let (g, locals, global) = setup(1, 8);
        let cfg = DistributedConfig {
            t: 800,
            k: 4,
            ..Default::default()
        };
        let mut rng = Pcg64::seed_from(2);
        let run = cluster_on_graph(&g, &locals, &cfg, &RustBackend, &mut rng).unwrap();
        assert_eq!(run.centers.n(), 4);
        assert!(run.comm_points > 0);

        // Solution quality on the *global* data vs direct clustering.
        let mut rng2 = Pcg64::seed_from(3);
        let direct = approx_solution(&global, 4, Objective::KMeans, &RustBackend, &mut rng2, 40);
        let run_cost = cost_of(&global, &run.centers, Objective::KMeans);
        let ratio = run_cost / direct.cost;
        assert!(ratio < 1.3, "cost ratio {ratio}");
    }

    #[test]
    fn graph_comm_matches_2m_formula() {
        let (g, locals, _) = setup(4, 6);
        let cfg = DistributedConfig {
            t: 300,
            k: 3,
            ..Default::default()
        };
        let mut rng = Pcg64::seed_from(5);
        let run = cluster_on_graph(&g, &locals, &cfg, &RustBackend, &mut rng).unwrap();
        // Flood #1: n scalars -> 2 m n. Flood #2: coreset points ->
        // 2 m (t + n k).
        let n = g.n();
        let expected = 2 * g.m() * n + 2 * g.m() * (cfg.t + n * cfg.k);
        assert_eq!(run.comm_points, expected);
    }

    #[test]
    fn tree_run_cheaper_than_graph_run() {
        let (g, locals, _) = setup(6, 10);
        let cfg = DistributedConfig {
            t: 500,
            k: 4,
            ..Default::default()
        };
        let mut rng = Pcg64::seed_from(7);
        let tree = SpanningTree::random_root(&g, &mut rng);
        let run_tree =
            cluster_on_tree(&tree, &locals, &cfg, &RustBackend, &mut rng).unwrap();
        let run_graph =
            cluster_on_graph(&g, &locals, &cfg, &RustBackend, &mut rng).unwrap();
        assert!(
            run_tree.comm_points < run_graph.comm_points,
            "tree {} !< graph {}",
            run_tree.comm_points,
            run_graph.comm_points
        );
        assert_eq!(run_tree.centers.n(), 4);
    }

    #[test]
    fn combine_runs_on_both_topologies() {
        let (g, locals, global) = setup(8, 6);
        let cfg = CombineConfig {
            t: 600,
            k: 4,
            objective: Objective::KMeans,
        };
        let mut rng = Pcg64::seed_from(9);
        let tree = SpanningTree::random_root(&g, &mut rng);
        let a = combine_on_graph(&g, &locals, &cfg, &RustBackend, &mut rng).unwrap();
        let b = combine_on_tree(&tree, &locals, &cfg, &RustBackend, &mut rng).unwrap();
        for run in [&a, &b] {
            let cost = cost_of(&global, &run.centers, Objective::KMeans);
            assert!(cost.is_finite() && cost > 0.0);
        }
    }

    #[test]
    fn zhang_runs_and_charges_tree_edges() {
        let (g, locals, global) = setup(10, 9);
        let mut rng = Pcg64::seed_from(11);
        let tree = SpanningTree::random_root(&g, &mut rng);
        let cfg = ZhangConfig {
            t_node: 120,
            k: 4,
            objective: Objective::KMeans,
        };
        let run = zhang_on_tree(&tree, &locals, &cfg, &RustBackend, &mut rng).unwrap();
        assert!(run.comm_points > 0);
        let cost = cost_of(&global, &run.centers, Objective::KMeans);
        assert!(cost.is_finite() && cost > 0.0);
    }
}
