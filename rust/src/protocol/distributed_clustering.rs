//! **Algorithm 2** — the end-to-end distributed clustering engine.
//!
//! One wire engine, [`stream_exchange`], runs any portion-producing
//! construction over either topology (general graph with flooding,
//! rooted tree with converge-cast), streaming the coreset exchange in
//! fixed-size pages through the bandwidth-limited network simulator so
//! every figure compares *measured* communication, rounds and peak
//! memory, not assumed bounds. Arriving pages fold into a mergeable
//! sketch ([`crate::sketch`]) at every collecting node — the collector
//! solves on `finish()` instead of reassembling the full coreset, and
//! in merge-and-reduce mode tree relays reduce their children's streams
//! in-network before forwarding. Bottom-up compositions (the
//! Zhang-et-al. baseline) share the session-driven metering plane
//! through [`run_composed`].
//!
//! Both engines are private details of [`crate::scenario::Scenario`] —
//! the typed builder is the one public run surface; the historical
//! `cluster_on_*` / `combine_on_*` / `zhang_on_tree*` entry points kept
//! here are thin shims over it (RNG draw order preserved, results
//! bit-identical — asserted by `tests/scenario_api.rs`).

// pallas-lint: allow(panic-free-protocol, file) — collector-side assembly over
// engine-built vectors: every index is a node id below n or a phase slot sized at
// construction, and the expects restate session invariants (one stream per node,
// the driven run left its results in place); a failure here is a bug, not a state.
use crate::clustering::backend::Backend;
use crate::clustering::{approx_solution, Objective, Solution};
use crate::coreset::distributed;
use crate::coreset::Coreset;
use crate::exec::ExecPolicy;
use crate::network::{paginate, ChannelConfig, Network, Payload};
use crate::points::{Dataset, WeightedSet};
use crate::protocol::broadcast_down;
use crate::protocol::session::{drive_with_mode, DriveMode, PipeMachine, Solver, ZhangMachine};
use crate::rng::Pcg64;
use crate::sketch::{SketchMode, SketchPlan};
use crate::topology::{Graph, SpanningTree};
use crate::trace::{keys, TraceLog, Tracer};
use std::collections::BTreeMap;
use std::sync::Arc;

/// Refinement iterations of the final coreset solve (matches the
/// experiment driver's baseline solves).
const FINAL_SOLVE_ITERS: usize = 40;

/// Outcome of one distributed clustering run.
#[derive(Clone, Debug)]
pub struct RunResult {
    /// The k centers of the final solution.
    pub centers: Dataset,
    /// Cost of the solution *on the coreset* (the solver's view).
    pub coreset_cost: f64,
    /// The global coreset the solution was computed on (the collector's
    /// finished sketch; in exact mode, byte-identical to the union of
    /// the portions; under the overlay exchange, the root's reduced set
    /// — exactly what flooded back to every node).
    pub coreset: Coreset,
    /// Total measured communication (points transmitted).
    pub comm_points: usize,
    /// Synchronous network rounds used (a real transfer time under a
    /// finite link capacity; phases overlap, so this is *not* the sum of
    /// per-primitive round counts).
    pub rounds: usize,
    /// Receiver-side *wire* buffer high-water mark in points (see
    /// [`Network::peak_points`]).
    pub peak_points: usize,
    /// Per-node *host* buffer high-water marks in points (sketch
    /// residency + relay backlog) — the node-side memory breakdown the
    /// wire meter cannot see. Indexed by node id. On a graph in
    /// merge-reduce mode only the collector materializes a sketch
    /// (other nodes forward and drop; a real deployment node running
    /// the same fold would obey the collector's bound).
    pub node_peaks: Vec<usize>,
    /// `node_peaks` at the collecting node — the memory the solve-side
    /// of the pipeline had to provision.
    pub collector_peak: usize,
    /// Which sketch folded the stream (`"exact"` / `"merge-reduce"`).
    pub sketch: &'static str,
    /// Algorithm label for reports.
    pub algorithm: &'static str,
    /// Extensible named meters, so future instrumentation stops forcing
    /// signature churn. Every key (and its one-line meaning) lives in
    /// the [`crate::trace::keys`] registry: the scheduling counters
    /// (`sched_ticks`, `sched_rounds`, `recv_drains`, `idle_recvs`) are
    /// always present; `mr_error_ppm` / `mr_reductions` appear on
    /// merge-and-reduce runs only (see [`RunResult::error_factor`]);
    /// and traced runs add the `phase_rounds_*` spans, `inflight_p99`
    /// and `trace_events` aggregates derived from the captured log.
    pub meters: BTreeMap<&'static str, u64>,
    /// The captured event log of a traced run (`None` when tracing was
    /// off — the default; capture is opt-in and bit-identical).
    pub trace: Option<TraceLog>,
}

impl RunResult {
    /// The composed merge-and-reduce error factor `Π(1 + ε_r)` measured
    /// over the worst reduction chain of this run — `1.0` for exact
    /// (lossless) folds. Decoded from the `mr_error_ppm` meter.
    pub fn error_factor(&self) -> f64 {
        1.0 + self.meters.get(keys::MR_ERROR_PPM).copied().unwrap_or(0) as f64 / 1e6
    }
}

/// Which topology the pipeline runs over.
#[derive(Clone, Copy)]
pub enum Topology<'a> {
    /// General graph: flooding for every exchange; all nodes end holding
    /// the full coreset and solve identically (no solution broadcast).
    Graph(&'a Graph),
    /// Rooted spanning tree (Theorem 3): converge-cast up, broadcast
    /// down, the root solves.
    Tree(&'a SpanningTree),
    /// Overlay-reduced graph exchange: costs flood the graph, portions
    /// converge-fold up a spanning-tree overlay of it (merge-and-reduce
    /// at every overlay relay), the overlay root solves on the reduced
    /// sketch, and only the reduced set + centers flood back over the
    /// graph edges — so every node still ends holding a coreset + the
    /// solution, at wire totals far below flooding the full stream.
    Overlay(&'a Graph, &'a SpanningTree),
}

fn solve_on(
    coreset: &Coreset,
    k: usize,
    cfg_obj: Objective,
    backend: &dyn Backend,
    rng: &mut Pcg64,
) -> Solution {
    approx_solution(&coreset.set, k, cfg_obj, backend, rng, FINAL_SOLVE_ITERS)
}

/// Worst leaf→root composition of per-node sketch error factors: every
/// reducing relay re-sketches what flows through it, so the stream
/// reaching the root through the loosest chain carries the product of
/// the factors along its path. Used for both explicit trees and the
/// spanning-tree overlay of a graph (the chains are overlay chains
/// there). With every factor ≥ 1 the composition is monotone in chain
/// depth — extending the worst chain can only raise the product
/// (pinned by `composed_error_factor_is_monotone_in_path_depth`).
fn composed_error_factor(tree: &SpanningTree, factors: &[f64]) -> f64 {
    fn walk(tree: &SpanningTree, factors: &[f64], v: usize) -> f64 {
        let through_children = tree.children[v]
            .iter()
            .map(|&c| walk(tree, factors, c))
            .fold(1.0_f64, f64::max);
        factors[v] * through_children
    }
    walk(tree, factors, tree.root)
}

/// The unified wire engine: stream already-built portions through the
/// paged message plane over `topology`, fold them into `sketch` at
/// every collecting node, solve at the collector, and meter everything.
///
/// Under the default exact sketch the compute schedule (and therefore
/// every RNG draw) is identical to the materialized drivers — the
/// construction drew round 1 then round 2 before this engine runs, and
/// the final solve consumes the same stream next; the wire phase itself
/// draws nothing. Results are therefore bit-compatible with the
/// monolithic exchange for every `channel` setting: paging, link
/// capacity and exact folding only reshape *when* points move and *how*
/// they are buffered, never *which* points feed the solve (verified on
/// every run: the collector's finished fold must reproduce the union of
/// the sent portions byte for byte). The merge-and-reduce sketch instead
/// trades a bounded accuracy loss for bounded memory — the collector
/// holds `O(levels · bucket_points)` instead of the full `t + nk`
/// coreset, and on a tree every relay reduces its subtree's stream
/// before forwarding, which *reduces total communication* as well.
/// Merge-and-reduce re-solves draw from dedicated per-node RNG streams,
/// never from the pipeline generator, and meter their measured composed
/// error factor into `RunResult::meters`.
///
/// The overlay topology composes both modes in one session: the cost
/// flood and the converge-fold overlap through the same per-node
/// readiness gating as always (a node streams into its sketch the
/// moment its own cost view completes, while costs still propagate
/// elsewhere), and the root's reduced-set flood rides the same drive
/// loop — no phase barrier anywhere.
#[allow(clippy::too_many_arguments)]
pub(crate) fn stream_exchange(
    topology: Topology<'_>,
    n: usize,
    portions: Vec<Coreset>,
    costs: Option<Vec<f64>>,
    k: usize,
    objective: Objective,
    algorithm: &'static str,
    channel: &ChannelConfig,
    sketch: &SketchPlan,
    mode: DriveMode,
    trace: bool,
    backend: &dyn Backend,
    rng: &mut Pcg64,
) -> anyhow::Result<RunResult> {
    anyhow::ensure!(portions.len() == n, "one portion per site");
    // The overlay exchange simulates on the *graph*: overlay-tree edges
    // are graph edges, so each hop pays the underlying directed edge's
    // LinkModel capacity — there is no separate "overlay channel".
    let graph = match topology {
        Topology::Graph(g) | Topology::Overlay(g, _) => g.clone(),
        Topology::Tree(t) => t.as_graph(),
    };
    anyhow::ensure!(graph.n() == n, "one local set per node");
    if let Topology::Overlay(_, tree) = topology {
        // Scenario validates these axes with user-facing messages; this
        // is the engine's own invariant (misuse is a driver bug).
        anyhow::ensure!(
            sketch.mode == SketchMode::MergeReduce && channel.page_points > 0,
            "overlay exchange needs merge-reduce folding and paging"
        );
        anyhow::ensure!(tree.n() == n, "overlay tree spans the graph");
    }
    // One tracer handle shared by the network, every machine and every
    // sketch (counts-only capture; `None` costs nothing and the traced
    // run is bit-identical — see `crate::trace`).
    let tracer = trace.then(Tracer::new);
    let mut net = Network::new(graph)
        .without_transcript()
        .with_link_model(channel.link_model())
        .with_tracer(tracer.clone());
    let shared = net.graph_shared();

    // Dedicated per-node streams for merge-and-reduce re-solves (exact
    // mode takes none, leaving the pipeline generator untouched — the
    // bit-compatibility contract).
    let merge_reduce = sketch.mode == SketchMode::MergeReduce;
    let mut sketch_streams: std::vec::IntoIter<Pcg64> = if merge_reduce {
        let mut master = rng.split();
        master.split_n(n).into_iter()
    } else {
        Vec::new().into_iter()
    };
    let mut node_sketch = |node_needs_fold: bool| {
        node_needs_fold.then(|| {
            let stream = if merge_reduce {
                sketch_streams.next().expect("one stream per node")
            } else {
                // pallas-lint: allow(rng-discipline) — dummy stream: exact sketches draw nothing
                Pcg64::seed_from(0)
            };
            sketch.build(k, objective, backend, stream)
        })
    };

    // Wire phase: one session where the cost exchange, the paged portion
    // streaming, in-network folding and (on trees) the solution
    // broadcast overlap.
    let pages: Vec<Vec<Payload>> = portions
        .iter()
        .enumerate()
        .map(|(i, c)| paginate(i, Arc::new(c.set.clone()), channel.page_points))
        .collect();
    let total_pages: usize = pages.iter().map(|p| p.len()).sum();
    let cost_payload = |i: usize| {
        costs.as_ref().map(|c| Payload::LocalCost {
            site: i,
            cost: c[i],
        })
    };
    let mut solver = Some(Solver {
        backend,
        rng: &mut *rng,
        k,
        objective,
        iters: FINAL_SOLVE_ITERS,
    });

    let (collector, mut nodes) = match topology {
        Topology::Graph(_) => {
            let nodes: Vec<PipeMachine> = pages
                .into_iter()
                .enumerate()
                .map(|(i, own)| {
                    // Exact mode: every node keeps the flooded stream
                    // (Arc views — Algorithm 2's all-nodes-hold
                    // semantics, metered per node). Merge-reduce: only
                    // the collector materializes a sketch — any node
                    // *could* run the identical bounded fold, but
                    // simulating n copies of the bucket re-solves would
                    // multiply wall-clock for no additional output.
                    let fold = if merge_reduce && i != 0 {
                        None
                    } else {
                        node_sketch(true)
                    };
                    PipeMachine::graph(
                        i,
                        Arc::clone(&shared),
                        cost_payload(i),
                        own,
                        n,
                        total_pages,
                        fold,
                        if i == 0 { solver.take() } else { None },
                    )
                    .with_tracer(tracer.clone())
                })
                .collect();
            (0usize, nodes)
        }
        Topology::Tree(tree) => {
            let total_cost: f64 = costs.as_ref().map_or(0.0, |c| c.iter().sum());
            let nodes: Vec<PipeMachine> = pages
                .into_iter()
                .enumerate()
                .map(|(v, own)| {
                    let is_root = v == tree.root;
                    // Exact: only the root folds (count-based); others
                    // relay verbatim. Merge-reduce: every node folds its
                    // subtree (site-based) and non-roots forward the
                    // reduced stream.
                    let (fold, pages_expected, sites_expected, reduce_relay) = if merge_reduce
                    {
                        (
                            node_sketch(true),
                            usize::MAX,
                            tree.children[v].len() + 1,
                            !is_root,
                        )
                    } else {
                        (
                            node_sketch(is_root),
                            if is_root { total_pages } else { usize::MAX },
                            0,
                            false,
                        )
                    };
                    PipeMachine::tree(
                        v,
                        (!is_root).then_some(tree.parent[v]),
                        tree.children[v].clone(),
                        cost_payload(v),
                        (is_root && costs.is_some())
                            .then_some(Payload::Scalar(total_cost)),
                        own,
                        n,
                        fold,
                        pages_expected,
                        sites_expected,
                        reduce_relay,
                        channel.page_points,
                        is_root.then(|| solver.take().expect("one solver")),
                    )
                    .with_tracer(tracer.clone())
                })
                .collect();
            (tree.root, nodes)
        }
        Topology::Overlay(_, tree) => {
            let nodes: Vec<PipeMachine> = pages
                .into_iter()
                .enumerate()
                .map(|(v, own)| {
                    let is_root = v == tree.root;
                    // Every overlay node folds its own portion plus one
                    // reduced portion per overlay child (site-based
                    // completion — empty sites count through their
                    // zero-cost page) and non-roots forward the reduced
                    // stream up the overlay.
                    PipeMachine::overlay(
                        v,
                        (!is_root).then_some(tree.parent[v]),
                        Arc::clone(&shared),
                        cost_payload(v),
                        own,
                        n,
                        node_sketch(true),
                        tree.children[v].len() + 1,
                        channel.page_points,
                        is_root.then(|| solver.take().expect("one solver")),
                    )
                    .with_tracer(tracer.clone())
                })
                .collect();
            (tree.root, nodes)
        }
    };
    let stats = drive_with_mode(&mut net, &mut nodes, mode);

    // Delivery checks: on a graph every node must have folded the whole
    // stream; on a tree the root must have completed its collection; on
    // an overlay every node must hold the root's full reduced-set flood
    // plus the centers.
    if matches!(topology, Topology::Graph(_)) {
        for (v, node) in nodes.iter().enumerate() {
            anyhow::ensure!(
                node.pages_collected() == total_pages,
                "node {v} folded {} of {total_pages} pages (disconnected graph?)",
                node.pages_collected()
            );
        }
    }
    if matches!(topology, Topology::Overlay(..)) {
        let expected = nodes[collector].bcast_pages_total;
        anyhow::ensure!(expected > 0, "overlay root never flooded its reduced set");
        for (v, node) in nodes.iter().enumerate() {
            anyhow::ensure!(
                node.bcast_pages_got == expected && node.centers_got,
                "node {v} holds {} of {expected} reduced pages (centers: {}) — \
                 disconnected graph?",
                node.bcast_pages_got,
                node.centers_got
            );
        }
    }
    let (solution, finished) = {
        let node = &mut nodes[collector];
        (node.solution.take(), node.finished.take())
    };
    let (sol, mut coreset) = match (solution, finished) {
        (Some(s), Some(c)) => (s, c),
        _ => anyhow::bail!("collector {collector} never completed its collection"),
    };

    // Exact mode must reproduce the materialized exchange byte for byte
    // — this runs on every call, so any paging/folding regression fails
    // loudly.
    if !merge_reduce {
        let expected = distributed::union(&portions);
        anyhow::ensure!(
            coreset.set == expected.set,
            "collector {collector}: folded stream does not reproduce the sent portions"
        );
        coreset.sampled = expected.sampled;
    }

    let node_peaks: Vec<usize> = nodes.iter().map(|m| m.node_peak).collect();
    let collector_peak = node_peaks[collector];
    let mut meters = BTreeMap::new();
    meters.insert(keys::SCHED_TICKS, stats.node_ticks);
    meters.insert(keys::SCHED_ROUNDS, stats.rounds);
    meters.insert(keys::RECV_DRAINS, net.recv_drains() as u64);
    meters.insert(keys::IDLE_RECVS, net.idle_recvs() as u64);
    if merge_reduce {
        let factors: Vec<f64> = nodes.iter().map(|m| m.sketch_error_factor).collect();
        let composed = match topology {
            Topology::Graph(_) => factors[collector],
            Topology::Tree(tree) | Topology::Overlay(_, tree) => {
                composed_error_factor(tree, &factors)
            }
        };
        meters.insert(
            keys::MR_ERROR_PPM,
            ((composed - 1.0).max(0.0) * 1e6).round() as u64,
        );
        meters.insert(
            keys::MR_REDUCTIONS,
            nodes.iter().map(|m| m.sketch_reductions).sum::<usize>() as u64,
        );
    }
    let trace_log = tracer.map(|t| {
        // Close the log with the self-check totals, then fold the
        // derived aggregates (phase spans, inflight p99, event count)
        // into the run's meters.
        t.summary(net.cost_points(), net.round(), net.dropped());
        let log = t.snapshot();
        for (key, value) in log.derived_meters() {
            meters.insert(key, value);
        }
        log
    });
    Ok(RunResult {
        centers: sol.centers,
        coreset_cost: sol.cost,
        coreset,
        comm_points: net.cost_points(),
        rounds: net.round(),
        peak_points: net.peak_points(),
        node_peaks,
        collector_peak,
        sketch: sketch.mode.name(),
        algorithm,
        meters,
        trace: trace_log,
    })
}

/// The composed-exchange wire engine (Zhang-et-al. shape): the coreset
/// was already built host-side bottom-up; charge each child → parent
/// summary transfer through the simulator under the channel's link
/// model, solve at the root, broadcast the centers down, and report the
/// per-node host buffers the composition needed.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_composed(
    tree: &SpanningTree,
    coreset: Coreset,
    sent_points: Vec<usize>,
    k: usize,
    objective: Objective,
    algorithm: &'static str,
    channel: &ChannelConfig,
    mode: DriveMode,
    trace: bool,
    backend: &dyn Backend,
    rng: &mut Pcg64,
) -> anyhow::Result<RunResult> {
    anyhow::ensure!(tree.n() == sent_points.len(), "one summary per node");
    // The composed exchange has no per-node phase machinery, so a trace
    // captures the wire layer only: per-edge flow and per-round totals.
    let tracer = trace.then(Tracer::new);
    let mut net = Network::new(tree.as_graph())
        .without_transcript()
        .with_link_model(channel.link_model())
        .with_tracer(tracer.clone());
    // Charge each child -> parent summary transfer with a metering-only
    // payload (the simulator never needs the summary's coordinates).
    // Every node waits for its children before emitting, so one session
    // moves whole tree levels per round. A node with nothing to send
    // still emits a zero-point payload — its parent must learn the
    // subtree is drained.
    let mut machines: Vec<ZhangMachine> = (0..tree.n())
        .map(|v| {
            let is_root = v == tree.root;
            ZhangMachine::new(
                (!is_root).then_some(tree.parent[v]),
                tree.children[v].len(),
                (!is_root).then_some(Payload::Opaque {
                    site: v,
                    points: sent_points[v],
                }),
            )
        })
        .collect();
    let stats = drive_with_mode(&mut net, &mut machines, mode);
    let sol = solve_on(&coreset, k, objective, backend, rng);
    broadcast_down(
        &mut net,
        tree,
        &Payload::Centers(Arc::new(sol.centers.clone())),
    );
    // Per-node host buffers, analogous to the pipeline's fold meter:
    // each node holds its own outgoing summary plus its children's
    // summaries until it has composed them; the root additionally holds
    // the final coreset.
    let mut node_peaks: Vec<usize> = (0..tree.n())
        .map(|v| {
            sent_points[v]
                + tree.children[v]
                    .iter()
                    .map(|&c| sent_points[c])
                    .sum::<usize>()
        })
        .collect();
    node_peaks[tree.root] = node_peaks[tree.root].max(coreset.size());
    let collector_peak = node_peaks[tree.root];
    let mut meters = BTreeMap::new();
    meters.insert(keys::SCHED_TICKS, stats.node_ticks);
    meters.insert(keys::SCHED_ROUNDS, stats.rounds);
    meters.insert(keys::RECV_DRAINS, net.recv_drains() as u64);
    meters.insert(keys::IDLE_RECVS, net.idle_recvs() as u64);
    let trace_log = tracer.map(|t| {
        t.summary(net.cost_points(), net.round(), net.dropped());
        let log = t.snapshot();
        for (key, value) in log.derived_meters() {
            meters.insert(key, value);
        }
        log
    });
    Ok(RunResult {
        centers: sol.centers,
        coreset_cost: sol.cost,
        coreset,
        comm_points: net.cost_points(),
        rounds: net.round(),
        peak_points: net.peak_points(),
        node_peaks,
        collector_peak,
        sketch: SketchMode::Exact.name(),
        algorithm,
        meters,
        trace: trace_log,
    })
}

// ---------------------------------------------------------------------
// Legacy entry points — thin shims over the Scenario builder.
// ---------------------------------------------------------------------

use crate::coreset::combine::CombineConfig;
use crate::coreset::distributed::DistributedConfig;
use crate::coreset::zhang::ZhangConfig;
use crate::scenario::{
    Combine as CombineAlgo, Distributed as DistributedAlgo, Scenario, Zhang as ZhangAlgo,
};

/// The paper's algorithm on a general graph: distributed coreset
/// construction with flooding for both the cost exchange and the coreset
/// exchange. Every node ends holding the full coreset (as in Algorithm
/// 2); the solver runs once since all nodes compute identically.
///
/// Sequential monolithic-exchange shim — see [`crate::scenario::Scenario`]
/// for paging, link models, sketched folding and parallel execution.
pub fn cluster_on_graph(
    graph: &Graph,
    locals: &[WeightedSet],
    cfg: &DistributedConfig,
    backend: &dyn Backend,
    rng: &mut Pcg64,
) -> anyhow::Result<RunResult> {
    cluster_on_graph_exec(graph, locals, cfg, backend, rng, ExecPolicy::Sequential)
}

/// [`cluster_on_graph`] under an explicit [`ExecPolicy`]: Round 1 and
/// Round 2 run per-site on worker threads (the network simulation — a
/// bookkeeping pass — stays on the caller's thread). Results are
/// independent of the thread count; see [`crate::exec`].
pub fn cluster_on_graph_exec(
    graph: &Graph,
    locals: &[WeightedSet],
    cfg: &DistributedConfig,
    backend: &dyn Backend,
    rng: &mut Pcg64,
    exec: ExecPolicy,
) -> anyhow::Result<RunResult> {
    Scenario::on_graph(graph.clone())
        .exec(exec)
        .run_with_rng(&DistributedAlgo(*cfg), locals, backend, rng)
}

/// The paper's algorithm on a rooted tree (Theorem 3): costs converge to
/// the root, the total broadcasts down, portions converge to the root,
/// the root solves and broadcasts the centers.
///
/// Sequential monolithic-exchange shim — see [`crate::scenario::Scenario`].
pub fn cluster_on_tree(
    tree: &SpanningTree,
    locals: &[WeightedSet],
    cfg: &DistributedConfig,
    backend: &dyn Backend,
    rng: &mut Pcg64,
) -> anyhow::Result<RunResult> {
    cluster_on_tree_exec(tree, locals, cfg, backend, rng, ExecPolicy::Sequential)
}

/// [`cluster_on_tree`] under an explicit [`ExecPolicy`] (same contract
/// as [`cluster_on_graph_exec`]).
pub fn cluster_on_tree_exec(
    tree: &SpanningTree,
    locals: &[WeightedSet],
    cfg: &DistributedConfig,
    backend: &dyn Backend,
    rng: &mut Pcg64,
    exec: ExecPolicy,
) -> anyhow::Result<RunResult> {
    Scenario::on_tree(tree.clone())
        .exec(exec)
        .run_with_rng(&DistributedAlgo(*cfg), locals, backend, rng)
}

/// COMBINE baseline on a general graph: local FL11 coresets flooded to
/// every node. Shim over [`crate::scenario::Scenario`].
pub fn combine_on_graph(
    graph: &Graph,
    locals: &[WeightedSet],
    cfg: &CombineConfig,
    backend: &dyn Backend,
    rng: &mut Pcg64,
) -> anyhow::Result<RunResult> {
    Scenario::on_graph(graph.clone()).run_with_rng(&CombineAlgo(*cfg), locals, backend, rng)
}

/// COMBINE baseline on a rooted tree: local coresets converge to the
/// root, which solves and broadcasts. Shim over
/// [`crate::scenario::Scenario`].
pub fn combine_on_tree(
    tree: &SpanningTree,
    locals: &[WeightedSet],
    cfg: &CombineConfig,
    backend: &dyn Backend,
    rng: &mut Pcg64,
) -> anyhow::Result<RunResult> {
    Scenario::on_tree(tree.clone()).run_with_rng(&CombineAlgo(*cfg), locals, backend, rng)
}

/// Zhang-et-al. baseline on a rooted tree: coreset-of-coresets composed
/// bottom-up, each hop charged through the simulator.
///
/// Sequential shim — see [`zhang_on_tree_exec`].
pub fn zhang_on_tree(
    tree: &SpanningTree,
    locals: &[WeightedSet],
    cfg: &ZhangConfig,
    backend: &dyn Backend,
    rng: &mut Pcg64,
) -> anyhow::Result<RunResult> {
    zhang_on_tree_exec(tree, locals, cfg, backend, rng, ExecPolicy::Sequential)
}

/// [`zhang_on_tree`] under an explicit [`ExecPolicy`]: the bottom-up
/// composition runs level-parallel on the execution engine (see
/// [`crate::coreset::zhang::build_on_tree_exec`]) and the summary
/// transfers run through the session engine, so `rounds` reflects
/// *pipelined tree levels* — all nodes of one depth transfer
/// concurrently — instead of one synchronous step per edge. Shim over
/// [`crate::scenario::Scenario`].
pub fn zhang_on_tree_exec(
    tree: &SpanningTree,
    locals: &[WeightedSet],
    cfg: &ZhangConfig,
    backend: &dyn Backend,
    rng: &mut Pcg64,
    exec: ExecPolicy,
) -> anyhow::Result<RunResult> {
    Scenario::on_tree(tree.clone())
        .exec(exec)
        .run_with_rng(&ZhangAlgo(*cfg), locals, backend, rng)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clustering::backend::RustBackend;
    use crate::clustering::{cost_of, Objective};
    use crate::coreset::zhang;
    use crate::data::synthetic::gaussian_mixture;
    use crate::partition::Scheme;
    use crate::topology::generators;

    fn setup(seed: u64, sites: usize) -> (Graph, Vec<WeightedSet>, WeightedSet) {
        let mut rng = Pcg64::seed_from(seed);
        let data = gaussian_mixture(&mut rng, 4_000, 5, 4);
        let g = generators::erdos_renyi_connected(&mut rng, sites, 0.3);
        let locals: Vec<WeightedSet> = Scheme::Weighted
            .partition_on(&data, &g, &mut rng)
            .into_iter()
            .map(WeightedSet::unit)
            .collect();
        let global = WeightedSet::union(locals.iter());
        (g, locals, global)
    }

    #[test]
    fn graph_run_produces_good_solution() {
        let (g, locals, global) = setup(1, 8);
        let cfg = DistributedConfig {
            t: 800,
            k: 4,
            ..Default::default()
        };
        let mut rng = Pcg64::seed_from(2);
        let run = cluster_on_graph(&g, &locals, &cfg, &RustBackend, &mut rng).unwrap();
        assert_eq!(run.centers.n(), 4);
        assert!(run.comm_points > 0);
        assert_eq!(run.sketch, "exact");
        assert_eq!(run.node_peaks.len(), g.n());
        assert_eq!(run.collector_peak, run.node_peaks[0]);
        // Exact folding holds the full coreset at the collector.
        assert_eq!(run.collector_peak, run.coreset.size());
        // Exact folds carry no error-accounting meters: factor 1. (The
        // scheduler meter is always present.)
        assert!(run.meters.keys().all(|m| !m.starts_with("mr_")));
        assert!(run.meters[keys::SCHED_TICKS] > 0);
        assert_eq!(run.error_factor(), 1.0);

        // Solution quality on the *global* data vs direct clustering.
        let mut rng2 = Pcg64::seed_from(3);
        let direct = approx_solution(&global, 4, Objective::KMeans, &RustBackend, &mut rng2, 40);
        let run_cost = cost_of(&global, &run.centers, Objective::KMeans);
        let ratio = run_cost / direct.cost;
        assert!(ratio < 1.3, "cost ratio {ratio}");
    }

    #[test]
    fn graph_comm_matches_2m_formula() {
        let (g, locals, _) = setup(4, 6);
        let cfg = DistributedConfig {
            t: 300,
            k: 3,
            ..Default::default()
        };
        let mut rng = Pcg64::seed_from(5);
        let run = cluster_on_graph(&g, &locals, &cfg, &RustBackend, &mut rng).unwrap();
        // Flood #1: n scalars -> 2 m n. Flood #2: coreset points ->
        // 2 m (t + n k).
        let n = g.n();
        let expected = 2 * g.m() * n + 2 * g.m() * (cfg.t + n * cfg.k);
        assert_eq!(run.comm_points, expected);
    }

    #[test]
    fn paged_exchange_charges_exactly_the_monolithic_total() {
        // Pages partition portions, so the 2m(t + nk) formula holds for
        // ANY page size — the header metadata rides free, like weights.
        let (g, locals, _) = setup(4, 6);
        let cfg = DistributedConfig {
            t: 300,
            k: 3,
            ..Default::default()
        };
        let n = g.n();
        let expected = 2 * g.m() * n + 2 * g.m() * (cfg.t + n * cfg.k);
        for page_points in [0usize, 17, 64, 4096] {
            let run = Scenario::on_graph(g.clone())
                .channel(ChannelConfig::uniform(page_points, 0))
                .seed(5)
                .run(&DistributedAlgo(cfg), &locals, &RustBackend)
                .unwrap();
            assert_eq!(run.comm_points, expected, "page_points={page_points}");
        }
    }

    #[test]
    fn paged_run_is_bit_identical_to_monolithic() {
        let (g, locals, _) = setup(6, 10);
        let cfg = DistributedConfig {
            t: 500,
            k: 4,
            ..Default::default()
        };
        let run_at = |channel: ChannelConfig| {
            Scenario::on_graph(g.clone())
                .channel(channel)
                .seed(9)
                .run(&DistributedAlgo(cfg), &locals, &RustBackend)
                .unwrap()
        };
        let mono = run_at(ChannelConfig::default());
        let paged = run_at(ChannelConfig::uniform(32, 32));
        assert_eq!(mono.centers, paged.centers, "paging must not change results");
        assert_eq!(mono.coreset.set, paged.coreset.set);
        assert_eq!(mono.comm_points, paged.comm_points);
        assert!(paged.rounds > mono.rounds, "capacity stretches rounds");
        assert!(
            paged.peak_points < mono.peak_points,
            "paged {} !< mono {}",
            paged.peak_points,
            mono.peak_points
        );
        // The *host-side* fold is the same either way in exact mode.
        assert_eq!(mono.collector_peak, paged.collector_peak);
    }

    #[test]
    fn tree_run_cheaper_than_graph_run() {
        let (g, locals, _) = setup(6, 10);
        let cfg = DistributedConfig {
            t: 500,
            k: 4,
            ..Default::default()
        };
        let mut rng = Pcg64::seed_from(7);
        let tree = SpanningTree::random_root(&g, &mut rng);
        let run_tree =
            cluster_on_tree(&tree, &locals, &cfg, &RustBackend, &mut rng).unwrap();
        let run_graph =
            cluster_on_graph(&g, &locals, &cfg, &RustBackend, &mut rng).unwrap();
        assert!(
            run_tree.comm_points < run_graph.comm_points,
            "tree {} !< graph {}",
            run_tree.comm_points,
            run_graph.comm_points
        );
        assert_eq!(run_tree.centers.n(), 4);
    }

    #[test]
    fn combine_runs_on_both_topologies() {
        let (g, locals, global) = setup(8, 6);
        let cfg = CombineConfig {
            t: 600,
            k: 4,
            objective: Objective::KMeans,
        };
        let mut rng = Pcg64::seed_from(9);
        let tree = SpanningTree::random_root(&g, &mut rng);
        let a = combine_on_graph(&g, &locals, &cfg, &RustBackend, &mut rng).unwrap();
        let b = combine_on_tree(&tree, &locals, &cfg, &RustBackend, &mut rng).unwrap();
        assert_eq!(a.algorithm, "combine");
        assert_eq!(b.algorithm, "combine (tree)");
        for run in [&a, &b] {
            let cost = cost_of(&global, &run.centers, Objective::KMeans);
            assert!(cost.is_finite() && cost > 0.0);
        }
    }

    #[test]
    fn paged_tree_pipeline_matches_monolithic_cost_accounting() {
        let (g, locals, _) = setup(8, 6);
        let cfg = DistributedConfig {
            t: 400,
            k: 4,
            ..Default::default()
        };
        let mut rng0 = Pcg64::seed_from(13);
        let tree = SpanningTree::random_root(&g, &mut rng0);
        let run_at = |channel: ChannelConfig| {
            Scenario::on_tree(tree.clone())
                .channel(channel)
                .seed(14)
                .run(&DistributedAlgo(cfg), &locals, &RustBackend)
                .unwrap()
        };
        let mono = run_at(ChannelConfig::default());
        let paged = run_at(ChannelConfig::uniform(16, 16));
        assert_eq!(mono.comm_points, paged.comm_points);
        assert_eq!(mono.centers, paged.centers);
    }

    #[test]
    fn merge_reduce_tree_cuts_relay_traffic() {
        // On a path every non-root node relays its whole subtree in
        // exact mode; in merge-and-reduce mode it forwards a reduced
        // stream instead, so total points transmitted must drop.
        let mut rng0 = Pcg64::seed_from(31);
        let data = gaussian_mixture(&mut rng0, 6_000, 4, 4);
        let locals: Vec<WeightedSet> = Scheme::Uniform
            .partition(&data, 6, &mut rng0)
            .unwrap()
            .into_iter()
            .map(WeightedSet::unit)
            .collect();
        let g = generators::path(6);
        let tree = SpanningTree::bfs(&g, 0);
        let cfg = DistributedConfig {
            t: 1_024,
            k: 4,
            ..Default::default()
        };
        let run_at = |plan: SketchPlan| {
            Scenario::on_tree(tree.clone())
                .channel(ChannelConfig::uniform(64, 0))
                .sketch(plan)
                .seed(32)
                .run(&DistributedAlgo(cfg), &locals, &RustBackend)
                .unwrap()
        };
        let exact = run_at(SketchPlan::exact());
        let reduced = run_at(SketchPlan::merge_reduce(128));
        assert_eq!(reduced.sketch, "merge-reduce");
        assert!(
            reduced.comm_points < exact.comm_points,
            "in-network reduction must cut traffic: {} !< {}",
            reduced.comm_points,
            exact.comm_points
        );
        assert!(
            reduced.collector_peak < exact.collector_peak,
            "root sketch {} !< materialized {}",
            reduced.collector_peak,
            exact.collector_peak
        );
        assert_eq!(reduced.centers.n(), 4);
        // Error accounting: relays re-sketch in-network, so the run's
        // composed factor covers the worst relay→root chain.
        assert!(reduced.error_factor() > 1.0, "reductions must be metered");
        assert!(reduced.meters[keys::MR_REDUCTIONS] > 0);
        // The reduced solution still clusters the data sensibly.
        let global = WeightedSet::union(locals.iter());
        let c_exact = cost_of(&global, &exact.centers, Objective::KMeans);
        let c_reduced = cost_of(&global, &reduced.centers, Objective::KMeans);
        assert!(
            c_reduced < 2.0 * c_exact,
            "reduced {c_reduced} vs exact {c_exact}"
        );
    }

    #[test]
    fn composed_error_factor_is_monotone_in_path_depth() {
        // The worst-chain composition over a path tree is the prefix
        // product of per-node factors ≥ 1, so deepening the overlay can
        // only raise (never lower) the composed factor — the algebraic
        // half of the overlay error-accounting contract.
        crate::testutil::for_all(
            24,
            61,
            |rng| {
                let len = 2 + rng.below(9);
                let factors: Vec<f64> =
                    (0..len).map(|_| 1.0 + rng.uniform() * 0.5).collect();
                factors
            },
            |factors| {
                let mut prev = 0.0_f64;
                for depth in 1..=factors.len() {
                    let tree =
                        SpanningTree::bfs(&generators::path(depth), 0);
                    let composed = composed_error_factor(&tree, &factors[..depth]);
                    let product: f64 = factors[..depth].iter().product();
                    crate::prop_assert!(
                        (composed - product).abs() < 1e-12 * product,
                        "path composition must be the chain product: {composed} vs {product}"
                    );
                    crate::prop_assert!(
                        composed >= prev,
                        "depth {depth}: composed {composed} < shallower {prev}"
                    );
                    prev = composed;
                }
                Ok(())
            },
        );

        // Branching: the worst chain wins, siblings don't multiply.
        let star = SpanningTree::bfs(&generators::star(4), 0);
        let composed = composed_error_factor(&star, &[1.5, 1.1, 1.3, 1.2]);
        assert!((composed - 1.5 * 1.3).abs() < 1e-12, "{composed}");
    }

    #[test]
    fn zhang_runs_and_charges_tree_edges() {
        let (g, locals, global) = setup(10, 9);
        let mut rng = Pcg64::seed_from(11);
        let tree = SpanningTree::random_root(&g, &mut rng);
        let cfg = ZhangConfig {
            t_node: 120,
            k: 4,
            objective: Objective::KMeans,
        };
        let run = zhang_on_tree(&tree, &locals, &cfg, &RustBackend, &mut rng).unwrap();
        assert!(run.comm_points > 0);
        let cost = cost_of(&global, &run.centers, Objective::KMeans);
        assert!(cost.is_finite() && cost > 0.0);
    }

    #[test]
    fn zhang_opaque_metering_matches_build_accounting() {
        // The simulator charge must equal the construction's own
        // sent_points accounting plus the centers broadcast.
        let (g, locals, _) = setup(10, 9);
        let mut rng = Pcg64::seed_from(16);
        let tree = SpanningTree::random_root(&g, &mut rng);
        let cfg = ZhangConfig {
            t_node: 90,
            k: 3,
            objective: Objective::KMeans,
        };
        let mut build_rng = Pcg64::seed_from(17);
        let built =
            zhang::build_on_tree(&locals, &tree, &cfg, &RustBackend, &mut build_rng);
        let mut rng2 = Pcg64::seed_from(17);
        let run = zhang_on_tree(&tree, &locals, &cfg, &RustBackend, &mut rng2).unwrap();
        let expected = zhang::communication(&built) + (tree.n() - 1) * run.centers.n();
        assert_eq!(run.comm_points, expected);
    }

    #[test]
    fn zhang_rounds_reflect_pipelined_levels() {
        // Star rooted at the hub: 8 summaries move in ONE round through
        // the session engine (plus quiescence detection and the centers
        // broadcast) — the legacy per-edge metering took a step per
        // child. A path still needs one round per level.
        let mut rng0 = Pcg64::seed_from(19);
        let data = gaussian_mixture(&mut rng0, 2_000, 3, 3);
        let locals: Vec<WeightedSet> = Scheme::Uniform
            .partition(&data, 9, &mut rng0)
            .unwrap()
            .into_iter()
            .map(WeightedSet::unit)
            .collect();
        let cfg = ZhangConfig {
            t_node: 60,
            k: 3,
            objective: Objective::KMeans,
        };
        let star_tree = SpanningTree::bfs(&generators::star(9), 0);
        let mut rng = Pcg64::seed_from(20);
        let run = zhang_on_tree(&star_tree, &locals, &cfg, &RustBackend, &mut rng).unwrap();
        assert!(
            run.rounds <= 4,
            "star summaries must pipeline into O(1) rounds, got {}",
            run.rounds
        );

        let path_tree = SpanningTree::bfs(&generators::path(9), 0);
        let mut rng = Pcg64::seed_from(21);
        let run = zhang_on_tree(&path_tree, &locals, &cfg, &RustBackend, &mut rng).unwrap();
        assert!(
            run.rounds >= path_tree.height(),
            "a path cannot beat one round per level: {} < {}",
            run.rounds,
            path_tree.height()
        );
    }
}
