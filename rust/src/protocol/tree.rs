//! Rooted-tree communication primitives (Theorem 3): converge-cast
//! (leaves → root, accumulating payload sets hop by hop) and broadcast
//! (root → leaves). Every hop moves through the [`Network`] simulator so
//! the `O(h · Σ|D_i|)` communication accounting is measured, not assumed.

use crate::network::{Network, Payload};
use crate::topology::SpanningTree;

/// Send every node's payload up to the root; the root receives all `n`
/// payloads (its own included in the return). Each payload crosses
/// `depth(origin)` edges, so total cost is `Σ_i depth_i · |I_i| ≤ h Σ|I_i|`.
///
/// Returns the payloads collected at the root, ordered by origin where
/// the payload carries one.
pub fn converge_cast(net: &mut Network, tree: &SpanningTree, payloads: Vec<Payload>) -> Vec<Payload> {
    let n = net.n();
    assert_eq!(payloads.len(), n);
    assert_eq!(tree.n(), n);
    // relay[v]: payloads waiting at v to move one hop up.
    let mut relay: Vec<Vec<Payload>> = payloads.into_iter().map(|p| vec![p]).collect();
    let mut at_root: Vec<Payload> = Vec::new();
    at_root.append(&mut relay[tree.root]);

    loop {
        let mut sent_any = false;
        for v in 0..n {
            if v == tree.root || relay[v].is_empty() {
                continue;
            }
            let parent = tree.parent[v];
            for p in relay[v].drain(..) {
                net.send(v, parent, p);
                sent_any = true;
            }
        }
        if !sent_any {
            break;
        }
        net.step();
        for v in 0..n {
            for (_, p) in net.recv_all(v) {
                if v == tree.root {
                    at_root.push(p);
                } else {
                    relay[v].push(p);
                }
            }
        }
    }
    at_root.sort_by_key(|p| p.flood_key().map(|k| k.1).unwrap_or(usize::MAX));
    at_root
}

/// Broadcast one payload from the root to every node (each edge carries
/// it exactly once: cost `(n-1) · |payload|`). Returns nothing; every
/// node is assumed to record it on receipt (the drivers do).
pub fn broadcast_down(net: &mut Network, tree: &SpanningTree, payload: &Payload) {
    // BFS order: parents before children, so one pass per depth level.
    let mut order: Vec<usize> = (0..tree.n()).collect();
    order.sort_by_key(|&v| tree.depth[v]);
    let mut pending = vec![false; tree.n()];
    pending[tree.root] = true;
    for &v in &order {
        if !pending[v] {
            continue;
        }
        for &c in &tree.children[v] {
            net.send(v, c, payload.clone());
            pending[c] = true;
        }
        net.step();
        // Drain inboxes (delivery only; content is `payload` everywhere).
        for u in 0..tree.n() {
            net.recv_all(u);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::generators;

    fn tree_over(g: crate::topology::Graph, root: usize) -> SpanningTree {
        SpanningTree::bfs(&g, root)
    }

    #[test]
    fn converge_cast_collects_everything() {
        let g = generators::grid(3, 3);
        let tree = tree_over(g.clone(), 4);
        let mut net = Network::new(g);
        let payloads: Vec<Payload> = (0..9)
            .map(|i| Payload::LocalCost {
                site: i,
                cost: i as f64,
            })
            .collect();
        let collected = converge_cast(&mut net, &tree, payloads);
        assert_eq!(collected.len(), 9);
        let sites: Vec<usize> = collected
            .iter()
            .map(|p| p.flood_key().unwrap().1)
            .collect();
        assert_eq!(sites, (0..9).collect::<Vec<_>>());
    }

    #[test]
    fn converge_cast_cost_is_sum_of_depths() {
        let g = generators::path(5);
        let tree = tree_over(g.clone(), 0); // depths 0,1,2,3,4
        let mut net = Network::new(g);
        let payloads: Vec<Payload> = (0..5)
            .map(|i| Payload::LocalCost {
                site: i,
                cost: 0.0,
            })
            .collect();
        converge_cast(&mut net, &tree, payloads);
        // Unit payloads: cost = Σ depth_i = 0+1+2+3+4 = 10.
        assert_eq!(net.cost_points(), 10);
    }

    #[test]
    fn broadcast_cost_is_n_minus_1() {
        let g = generators::grid(3, 3);
        let tree = tree_over(g.clone(), 0);
        let mut net = Network::new(g);
        broadcast_down(&mut net, &tree, &Payload::Scalar(7.0));
        assert_eq!(net.cost_points(), 8);
    }

    #[test]
    fn broadcast_reaches_leaves_of_deep_tree() {
        let g = generators::path(6);
        let tree = tree_over(g.clone(), 0);
        let mut net = Network::new(g);
        // Track delivery by transcript: edge (4,5) must carry the payload.
        broadcast_down(&mut net, &tree, &Payload::Scalar(1.0));
        let t = net.transcript();
        assert!(t.iter().any(|e| e.from == 4 && e.to == 5));
        assert_eq!(t.len(), 5);
    }
}
