//! Rooted-tree communication primitives (Theorem 3): converge-cast
//! (leaves → root, payloads moving one hop per round) and broadcast
//! (root → leaves). Every hop moves through the [`Network`] simulator so
//! the `O(h · Σ|D_i|)` communication accounting is measured, not assumed.
//!
//! Implemented as [`ConvergeMachine`]/[`BroadcastMachine`] state
//! machines under the unified [`session`](super::session) round loop.

// pallas-lint: allow(panic-free-protocol[index], file) — node ids come from the
// spanning tree over the same graph, so every index is < n by construction.
use super::session::{drive, BroadcastMachine, ConvergeMachine};
use crate::network::{Network, Payload};
use crate::topology::SpanningTree;

/// Send every node's payload up to the root; the root receives all `n`
/// payloads (its own included in the return). Each payload crosses
/// `depth(origin)` edges, so total cost is `Σ_i depth_i · |I_i| ≤ h Σ|I_i|`.
///
/// Returns the payloads collected at the root, ordered by origin where
/// the payload carries one.
pub fn converge_cast(
    net: &mut Network,
    tree: &SpanningTree,
    payloads: Vec<Payload>,
) -> Vec<Payload> {
    let n = net.n();
    assert_eq!(payloads.len(), n);
    assert_eq!(tree.n(), n);
    converge_cast_multi(net, tree, payloads.into_iter().map(|p| vec![p]).collect())
}

/// [`converge_cast`] with any number of payloads per node (e.g. portion
/// pages). Total cost `Σ_i depth_i · |origins[i]|` in points.
pub fn converge_cast_multi(
    net: &mut Network,
    tree: &SpanningTree,
    origins: Vec<Vec<Payload>>,
) -> Vec<Payload> {
    let n = net.n();
    assert_eq!(origins.len(), n);
    assert_eq!(tree.n(), n);
    let mut nodes: Vec<ConvergeMachine> = origins
        .into_iter()
        .enumerate()
        .map(|(v, own)| {
            let parent = (v != tree.root).then_some(tree.parent[v]);
            ConvergeMachine::new(parent, own)
        })
        .collect();
    drive(net, &mut nodes);
    let mut at_root = std::mem::take(&mut nodes[tree.root].collected);
    at_root.sort_by_key(|p| p.flood_key().map_or(usize::MAX, |k| k.1));
    at_root
}

/// Broadcast one payload from the root to every node (each tree edge
/// carries it exactly once: cost `(n-1) · |payload|`). Returns nothing;
/// every node is assumed to record it on receipt (the drivers do).
pub fn broadcast_down(net: &mut Network, tree: &SpanningTree, payload: &Payload) {
    let n = tree.n();
    assert_eq!(net.n(), n);
    let mut nodes: Vec<BroadcastMachine> = (0..n)
        .map(|v| {
            let origin = (v == tree.root).then(|| payload.clone());
            BroadcastMachine::new(tree.children[v].clone(), origin)
        })
        .collect();
    drive(net, &mut nodes);
    debug_assert!(nodes.iter().all(|m| m.received), "broadcast incomplete");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::network::{paginate, reassemble, LinkModel};
    use crate::points::WeightedSet;
    use crate::rng::Pcg64;
    use crate::topology::generators;
    use std::sync::Arc;

    fn tree_over(g: crate::topology::Graph, root: usize) -> SpanningTree {
        SpanningTree::bfs(&g, root)
    }

    #[test]
    fn converge_cast_collects_everything() {
        let g = generators::grid(3, 3);
        let tree = tree_over(g.clone(), 4);
        let mut net = Network::new(g);
        let payloads: Vec<Payload> = (0..9)
            .map(|i| Payload::LocalCost {
                site: i,
                cost: i as f64,
            })
            .collect();
        let collected = converge_cast(&mut net, &tree, payloads);
        assert_eq!(collected.len(), 9);
        let sites: Vec<usize> = collected
            .iter()
            .map(|p| p.flood_key().unwrap().1)
            .collect();
        assert_eq!(sites, (0..9).collect::<Vec<_>>());
    }

    #[test]
    fn converge_cast_cost_is_sum_of_depths() {
        let g = generators::path(5);
        let tree = tree_over(g.clone(), 0); // depths 0,1,2,3,4
        let mut net = Network::new(g);
        let payloads: Vec<Payload> = (0..5)
            .map(|i| Payload::LocalCost {
                site: i,
                cost: 0.0,
            })
            .collect();
        converge_cast(&mut net, &tree, payloads);
        // Unit payloads: cost = Σ depth_i = 0+1+2+3+4 = 10.
        assert_eq!(net.cost_points(), 10);
    }

    #[test]
    fn broadcast_cost_is_n_minus_1() {
        let g = generators::grid(3, 3);
        let tree = tree_over(g.clone(), 0);
        let mut net = Network::new(g);
        broadcast_down(&mut net, &tree, &Payload::Scalar(7.0));
        assert_eq!(net.cost_points(), 8);
    }

    #[test]
    fn broadcast_reaches_leaves_of_deep_tree() {
        let g = generators::path(6);
        let tree = tree_over(g.clone(), 0);
        let mut net = Network::new(g);
        // Track delivery by transcript: edge (4,5) must carry the payload.
        broadcast_down(&mut net, &tree, &Payload::Scalar(1.0));
        let t = net.transcript();
        assert!(t.iter().any(|e| e.from == 4 && e.to == 5));
        assert_eq!(t.len(), 5);
    }

    #[test]
    fn paged_converge_cast_reassembles_at_root() {
        let mut rng = Pcg64::seed_from(3);
        let g = generators::grid(3, 3);
        let tree = tree_over(g.clone(), 4);
        let portions: Vec<Arc<WeightedSet>> = (0..9)
            .map(|_| {
                let mut s = WeightedSet::empty(2);
                for _ in 0..(5 + rng.below(20)) {
                    s.push(&[rng.normal() as f32, rng.normal() as f32], 1.0);
                }
                Arc::new(s)
            })
            .collect();
        let origins: Vec<Vec<Payload>> = portions
            .iter()
            .enumerate()
            .map(|(i, p)| paginate(i, p.clone(), 4))
            .collect();
        let mut net = Network::new(tree.as_graph())
            .without_transcript()
            .with_link_model(LinkModel::capped(4));
        let at_root = converge_cast_multi(&mut net, &tree, origins);
        let back = reassemble(&at_root).unwrap();
        assert_eq!(back.len(), 9);
        for (site, set) in back {
            assert_eq!(set, *portions[site]);
        }
        // Cost: each page crosses depth(origin) edges.
        let expect: usize = (0..9).map(|v| tree.depth[v] * portions[v].n()).sum();
        assert_eq!(net.cost_points(), expect);
    }
}
