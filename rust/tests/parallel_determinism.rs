//! Parallel execution engine — end-to-end determinism and regression
//! coverage for the panics fixed alongside it.
//!
//! Contract under test (see `distclus::exec`): with a fixed seed, the
//! parallel path produces *identical* results for any worker-thread
//! count, both at the per-site level (round1/round2 on worker threads)
//! and at the kernel level (chunk-parallel assign/lloyd).

use distclus::clustering::backend::{ParallelBackend, RustBackend};
use distclus::coreset::distributed::{self, DistributedConfig};
use distclus::coreset::Coreset;
use distclus::exec::ExecPolicy;
use distclus::network::ChannelConfig;
use distclus::partition::{PartitionError, Scheme};
use distclus::points::WeightedSet;
use distclus::protocol::cluster_on_graph_exec;
use distclus::rng::Pcg64;
use distclus::scenario::{Distributed, Scenario};
use distclus::testutil::mixture_sites;
use distclus::topology::generators;

fn sites(seed: u64, n: usize, count: usize) -> Vec<WeightedSet> {
    mixture_sites(seed, n, 6, 4, count, Scheme::Weighted, true)
}

fn portions_at(threads: usize, locals: &[WeightedSet]) -> Vec<Coreset> {
    let cfg = DistributedConfig {
        t: 500,
        k: 4,
        ..Default::default()
    };
    let mut rng = Pcg64::seed_from(99);
    distributed::build_portions_exec(
        locals,
        &cfg,
        &RustBackend,
        &mut rng,
        ExecPolicy::parallel(threads),
    )
}

#[test]
fn same_seed_identical_portions_for_1_2_and_8_threads() {
    let locals = sites(1, 5_000, 6);
    let one = portions_at(1, &locals);
    let two = portions_at(2, &locals);
    let eight = portions_at(8, &locals);
    assert_eq!(one.len(), two.len());
    for ((a, b), c) in one.iter().zip(&two).zip(&eight) {
        assert_eq!(a.sampled, b.sampled);
        assert_eq!(a.sampled, c.sampled);
        assert_eq!(a.set, b.set, "portions must be bit-identical");
        assert_eq!(a.set, c.set, "portions must be bit-identical");
    }
}

#[test]
fn full_protocol_identical_across_thread_counts_and_backends() {
    // End-to-end Algorithm 1+2 over a graph: per-site parallelism AND
    // kernel parallelism at once; centers and measured communication
    // must not depend on either thread count.
    let locals = sites(2, 4_000, 9);
    let g = generators::grid(3, 3);
    let cfg = DistributedConfig {
        t: 400,
        k: 4,
        ..Default::default()
    };
    let run = |site_threads: usize, kernel_threads: usize| {
        let backend = ParallelBackend::new(kernel_threads);
        let mut rng = Pcg64::seed_from(7);
        cluster_on_graph_exec(
            &g,
            &locals,
            &cfg,
            &backend,
            &mut rng,
            ExecPolicy::parallel(site_threads),
        )
        .unwrap()
    };
    let a = run(1, 1);
    let b = run(4, 2);
    let c = run(8, 8);
    assert_eq!(a.centers, b.centers);
    assert_eq!(a.centers, c.centers);
    assert_eq!(a.comm_points, b.comm_points);
    assert_eq!(a.comm_points, c.comm_points);
    assert_eq!(a.coreset.set, b.coreset.set);
    assert_eq!(a.coreset.set, c.coreset.set);
    // The new meters are simulation-side quantities: they must be as
    // thread-count invariant as everything else.
    assert_eq!(a.rounds, b.rounds);
    assert_eq!(a.rounds, c.rounds);
    assert_eq!(a.peak_points, b.peak_points);
    assert_eq!(a.peak_points, c.peak_points);
}

#[test]
fn paged_pipeline_meters_are_thread_count_invariant() {
    // With paging + a finite link capacity the simulated timeline is
    // richer (readiness-gated launches, capacity queuing) — rounds and
    // peak_points must still be a pure function of the seed.
    let locals = sites(5, 4_000, 8);
    let g = generators::path(locals.len());
    let cfg = DistributedConfig {
        t: 512,
        k: 4,
        ..Default::default()
    };
    let channel = ChannelConfig::uniform(32, 32);
    let run = |site_threads: usize| {
        Scenario::on_graph(g.clone())
            .channel(channel.clone())
            .exec(ExecPolicy::parallel(site_threads))
            .seed(21)
            .run(&Distributed(cfg), &locals, &RustBackend)
            .unwrap()
    };
    let a = run(1);
    let b = run(3);
    let c = run(8);
    assert_eq!(a.centers, b.centers);
    assert_eq!(a.centers, c.centers);
    assert_eq!(a.comm_points, b.comm_points);
    assert_eq!(a.rounds, b.rounds);
    assert_eq!(a.rounds, c.rounds);
    assert_eq!(a.peak_points, b.peak_points);
    assert_eq!(a.peak_points, c.peak_points);
    // The node-side fold meter is simulation state like the rest.
    assert_eq!(a.collector_peak, b.collector_peak);
    assert_eq!(a.node_peaks, c.node_peaks);
}

#[test]
fn parallel_backend_solution_quality_matches_sequential_setup() {
    // The parallel engine is not just deterministic — it must still
    // produce a valid construction (budget fully spent, k centers).
    let locals = sites(3, 6_000, 5);
    let portions = portions_at(0, &locals); // auto thread count
    let coreset = distributed::union(&portions);
    assert_eq!(coreset.sampled, 500);
    assert_eq!(coreset.size(), 500 + locals.len() * 4);
}

#[test]
fn degree_partition_is_an_error_via_public_api() {
    let mut rng = Pcg64::seed_from(4);
    let data = distclus::data::synthetic::gaussian_mixture(&mut rng, 200, 3, 2);
    let err = Scheme::Degree.partition(&data, 4, &mut rng).unwrap_err();
    assert!(matches!(err, PartitionError::NeedsGraph(Scheme::Degree)));
    // With the graph it succeeds, as before.
    let g = generators::star(4);
    let parts = Scheme::Degree.partition_on(&data, &g, &mut rng);
    assert_eq!(parts.iter().map(|p| p.n()).sum::<usize>(), 200);
}

#[test]
fn allocate_budget_non_finite_regression() {
    // Used to panic in the largest-remainder sort on NaN local costs.
    let alloc = distributed::allocate_budget(100, &[f64::NAN, 2.0, f64::INFINITY, 6.0]);
    assert_eq!(alloc.iter().sum::<usize>(), 100);
    assert_eq!(alloc[0], 0);
    assert_eq!(alloc[2], 0);
    assert_eq!(alloc[1], 25);
    assert_eq!(alloc[3], 75);
}

#[test]
fn erdos_renyi_connected_never_aborts_on_tiny_p() {
    let mut rng = Pcg64::seed_from(5);
    let g = generators::erdos_renyi_connected(&mut rng, 20, 1e-6);
    assert_eq!(g.n(), 20);
    assert!(distclus::topology::connected(&g));
}
