//! CLI black-box tests: spawn the real binaries and assert on their
//! observable behaviour (exit codes, stdout shape, artifacts on disk).

use std::path::Path;
use std::process::Command;

fn distclus() -> Command {
    Command::new(env!("CARGO_BIN_EXE_distclus"))
}

#[test]
fn info_lists_datasets_and_algorithms() {
    let out = distclus().arg("info").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for name in ["synthetic", "spam", "pendigits", "letter", "colorhist", "msd"] {
        assert!(text.contains(name), "missing dataset {name}");
    }
    assert!(text.contains("zhang-tree"));
}

#[test]
fn run_small_experiment_prints_report() {
    let out = distclus()
        .args([
            "run",
            "--dataset",
            "synthetic",
            "--scale",
            "0.01",
            "--topology",
            "grid",
            "--rows",
            "2",
            "--cols",
            "2",
            "--partition",
            "uniform",
            "--algorithm",
            "combine",
            "--t",
            "100",
            "--reps",
            "1",
            "--seed",
            "3",
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("synthetic/grid-uniform/combine"));
    assert!(text.contains("ratio(mean)"));
}

#[test]
fn run_writes_json_series() {
    let tmp = std::env::temp_dir().join("distclus_cli_test.json");
    let _ = std::fs::remove_file(&tmp);
    let out = distclus()
        .args([
            "run",
            "--dataset",
            "synthetic",
            "--scale",
            "0.01",
            "--topology",
            "star",
            "--sites",
            "4",
            "--algorithm",
            "distributed",
            "--t",
            "100",
            "--reps",
            "1",
            "--json",
            tmp.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success());
    let text = std::fs::read_to_string(&tmp).unwrap();
    assert!(text.contains("ratio_mean"), "json: {text}");
    let _ = std::fs::remove_file(&tmp);
}

#[test]
fn paged_channel_flags_accepted() {
    let out = distclus()
        .args([
            "run",
            "--dataset",
            "synthetic",
            "--scale",
            "0.01",
            "--topology",
            "star",
            "--sites",
            "4",
            "--algorithm",
            "distributed",
            "--t",
            "100",
            "--reps",
            "1",
            "--seed",
            "3",
            "--page-points",
            "16",
            "--link-capacity",
            "16",
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("peak(points)"), "report: {text}");
}

#[test]
fn sketch_flags_accepted_and_reported() {
    let out = distclus()
        .args([
            "run",
            "--dataset",
            "synthetic",
            "--scale",
            "0.01",
            "--topology",
            "star",
            "--sites",
            "4",
            "--algorithm",
            "distributed",
            "--t",
            "200",
            "--reps",
            "1",
            "--seed",
            "3",
            "--page-points",
            "16",
            "--sketch",
            "merge-reduce",
            "--bucket-points",
            "64",
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("node-peak"), "report: {text}");
    assert!(text.contains("merge-reduce"), "report: {text}");

    let out = distclus()
        .args(["run", "--sketch", "lossy"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("lossy"));
}

#[test]
fn overlay_exchange_flag_runs_and_misconfigs_fail() {
    let base = [
        "run",
        "--dataset",
        "synthetic",
        "--scale",
        "0.01",
        "--topology",
        "star",
        "--sites",
        "4",
        "--algorithm",
        "distributed",
        "--t",
        "200",
        "--reps",
        "1",
        "--seed",
        "3",
        "--exchange",
        "overlay",
    ];
    let out = distclus()
        .args(base)
        .args(["--page-points", "16", "--sketch", "merge-reduce", "--bucket-points", "64"])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("+overlay"), "report: {text}");

    // The overlay requires the merge-reduce sketch — loud, not silent.
    let out = distclus().args(base).args(["--page-points", "16"]).output().unwrap();
    assert!(!out.status.success());
    assert!(
        String::from_utf8_lossy(&out.stderr).contains("merge-reduce"),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );

    // And a tree algorithm cannot take a graph-mode exchange.
    let out = distclus()
        .args([
            "run",
            "--dataset",
            "synthetic",
            "--scale",
            "0.01",
            "--algorithm",
            "distributed-tree",
            "--t",
            "100",
            "--reps",
            "1",
            "--exchange",
            "overlay",
        ])
        .output()
        .unwrap();
    assert!(!out.status.success());
}

#[test]
fn degraded_link_flag_is_accepted_and_reported() {
    let out = distclus()
        .args([
            "run",
            "--dataset",
            "synthetic",
            "--scale",
            "0.01",
            "--topology",
            "star",
            "--sites",
            "4",
            "--algorithm",
            "distributed",
            "--t",
            "100",
            "--reps",
            "1",
            "--seed",
            "3",
            "--page-points",
            "16",
            "--link-capacity",
            "64",
            "--degraded",
            "1-0 @ 4",
            "--json",
        ])
        .arg(std::env::temp_dir().join("distclus_degraded_test.json"))
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let tmp = std::env::temp_dir().join("distclus_degraded_test.json");
    let text = std::fs::read_to_string(&tmp).unwrap();
    assert!(
        text.contains("cap=64; 0->1@4; 1->0@4"),
        "link profile must reach the JSON report: {text}"
    );
    let _ = std::fs::remove_file(&tmp);
}

#[test]
fn rejects_unknown_flags_and_values() {
    let out = distclus()
        .args(["run", "--bogus-flag", "1"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let out = distclus()
        .args(["run", "--algorithm", "sorcery"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("sorcery"), "stderr: {err}");
}

#[test]
fn no_subcommand_shows_usage() {
    let out = distclus().output().unwrap();
    assert_eq!(out.status.code(), Some(2));
    assert!(String::from_utf8_lossy(&out.stderr).contains("usage:"));
}

#[test]
fn coreset_subcommand_dumps_csv() {
    let tmp = std::env::temp_dir().join("distclus_coreset_test.csv");
    let _ = std::fs::remove_file(&tmp);
    let out = distclus()
        .args([
            "coreset",
            "--dataset",
            "synthetic",
            "--scale",
            "0.01",
            "--topology",
            "grid",
            "--rows",
            "2",
            "--cols",
            "2",
            "--algorithm",
            "distributed",
            "--t",
            "50",
            "--out",
            tmp.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = std::fs::read_to_string(&tmp).unwrap();
    // 50 samples + 4 sites * 5 centers rows, 10 coords + weight each.
    let rows: Vec<&str> = text.lines().collect();
    assert_eq!(rows.len(), 50 + 4 * 5, "rows: {}", rows.len());
    assert_eq!(rows[0].split(',').count(), 11);
    let _ = std::fs::remove_file(&tmp);
}

#[test]
fn csv_dataset_round_trip() {
    // Dump a coreset, reload it as a csv dataset through `run`.
    let tmp = std::env::temp_dir().join("distclus_csv_roundtrip.csv");
    let _ = std::fs::remove_file(&tmp);
    let ok = distclus()
        .args([
            "coreset",
            "--dataset",
            "synthetic",
            "--scale",
            "0.01",
            "--topology",
            "star",
            "--sites",
            "3",
            "--algorithm",
            "combine",
            "--t",
            "60",
            "--out",
            tmp.to_str().unwrap(),
        ])
        .status()
        .unwrap();
    assert!(ok.success());
    let out = distclus()
        .args([
            "run",
            "--dataset",
            &format!("csv:{}", tmp.display()),
            "--topology",
            "star",
            "--sites",
            "3",
            "--algorithm",
            "combine",
            "--k",
            "5",
            "--t",
            "40",
            "--reps",
            "1",
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let _ = std::fs::remove_file(&tmp);
    assert!(Path::new(env!("CARGO_BIN_EXE_figures")).exists());
}

#[test]
fn figures_rejects_unknown_subcommand() {
    let out = Command::new(env!("CARGO_BIN_EXE_figures"))
        .arg("fig99")
        .output()
        .unwrap();
    assert!(!out.status.success());
}
