//! Edge-case integration tests: degenerate topologies, degenerate data,
//! and boundary parameters through the full pipeline.

use distclus::clustering::backend::RustBackend;
use distclus::clustering::{approx_solution, Objective};
use distclus::coreset::zhang::ZhangConfig;
use distclus::coreset::DistributedConfig;
use distclus::network::{Network, Payload};
use distclus::points::{Dataset, WeightedSet};
use distclus::protocol::{cluster_on_graph, cluster_on_tree, flood, zhang_on_tree};
use distclus::rng::Pcg64;
use distclus::topology::{generators, Graph, SpanningTree};

#[test]
fn single_site_reduces_to_centralized() {
    let mut rng = Pcg64::seed_from(1);
    let data = distclus::data::synthetic::gaussian_mixture(&mut rng, 1_000, 4, 3);
    let g = Graph::empty(1);
    let locals = vec![WeightedSet::unit(data.clone())];
    let run = cluster_on_graph(
        &g,
        &locals,
        &DistributedConfig {
            t: 200,
            k: 3,
            ..Default::default()
        },
        &RustBackend,
        &mut rng,
    )
    .unwrap();
    assert_eq!(run.comm_points, 0, "single node never transmits");
    assert_eq!(run.centers.n(), 3);
    assert_eq!(run.coreset.size(), 200 + 3);
}

#[test]
fn single_node_flood_is_trivial() {
    let mut net = Network::new(Graph::empty(1));
    let held = flood(
        &mut net,
        vec![Payload::LocalCost { site: 0, cost: 1.0 }],
    );
    assert_eq!(held[0].len(), 1);
    assert_eq!(net.cost_points(), 0);
}

#[test]
fn two_node_tree_pipeline() {
    let mut rng = Pcg64::seed_from(2);
    let data = distclus::data::synthetic::gaussian_mixture(&mut rng, 500, 3, 2);
    let g = generators::path(2);
    let half = data.n() / 2;
    let locals = vec![
        WeightedSet::unit(data.gather(&(0..half).collect::<Vec<_>>())),
        WeightedSet::unit(data.gather(&(half..data.n()).collect::<Vec<_>>())),
    ];
    let tree = SpanningTree::bfs(&g, 0);
    let run = cluster_on_tree(
        &tree,
        &locals,
        &DistributedConfig {
            t: 100,
            k: 2,
            ..Default::default()
        },
        &RustBackend,
        &mut rng,
    )
    .unwrap();
    assert!(run.comm_points > 0);
    assert_eq!(run.centers.n(), 2);
}

#[test]
fn identical_points_everywhere() {
    // All data identical: every algorithm must return finite results and
    // a zero-cost solution.
    let mut rng = Pcg64::seed_from(3);
    let data = Dataset::from_flat(vec![2.5f32, -1.0].repeat(400), 2);
    let g = generators::grid(2, 2);
    let locals: Vec<WeightedSet> = (0..4)
        .map(|i| {
            WeightedSet::unit(data.gather(&(i * 100..(i + 1) * 100).collect::<Vec<_>>()))
        })
        .collect();
    let run = cluster_on_graph(
        &g,
        &locals,
        &DistributedConfig {
            t: 50,
            k: 3,
            ..Default::default()
        },
        &RustBackend,
        &mut rng,
    )
    .unwrap();
    assert!(run.coreset_cost.abs() < 1e-6, "cost {}", run.coreset_cost);
    assert_eq!(run.centers.row(0), &[2.5, -1.0]);
}

#[test]
fn k_larger_than_site_points() {
    // k=5 but some sites hold fewer than 5 points: local solves must
    // degrade gracefully (fewer effective centers) and the pipeline
    // still produce k global centers from the coreset.
    let mut rng = Pcg64::seed_from(4);
    let data = distclus::data::synthetic::gaussian_mixture(&mut rng, 40, 3, 5);
    let g = generators::path(8);
    let locals: Vec<WeightedSet> = (0..8)
        .map(|i| {
            WeightedSet::unit(data.gather(&(i * 5..(i + 1) * 5).collect::<Vec<_>>()))
        })
        .collect();
    let run = cluster_on_graph(
        &g,
        &locals,
        &DistributedConfig {
            t: 30,
            k: 5,
            ..Default::default()
        },
        &RustBackend,
        &mut rng,
    )
    .unwrap();
    assert!(run.centers.n() >= 1 && run.centers.n() <= 5);
    assert!(run.coreset_cost.is_finite());
}

#[test]
fn zhang_on_star_tree_is_single_hop() {
    let mut rng = Pcg64::seed_from(5);
    let data = distclus::data::synthetic::gaussian_mixture(&mut rng, 2_000, 4, 3);
    let g = generators::star(5);
    let locals: Vec<WeightedSet> = (0..5)
        .map(|i| {
            WeightedSet::unit(data.gather(&(i * 400..(i + 1) * 400).collect::<Vec<_>>()))
        })
        .collect();
    let tree = SpanningTree::bfs(&g, 0);
    let run = zhang_on_tree(
        &tree,
        &locals,
        &ZhangConfig {
            t_node: 100,
            k: 3,
            objective: Objective::KMeans,
        },
        &RustBackend,
        &mut rng,
    )
    .unwrap();
    // Leaves each send one summary; root sends centers back: 4 hops +
    // 4 center broadcasts.
    assert!(run.comm_points > 0);
    assert_eq!(run.rounds > 0, true);
}

#[test]
fn huge_t_saturates_at_data_size() {
    // t >> |P|: sampling with replacement still works; coreset bigger
    // than the data is wasteful but legal, and quality is near-exact.
    let mut rng = Pcg64::seed_from(6);
    // Well-separated blobs so both solves share one clear optimum and
    // the ratio isolates the coreset (not seeding luck).
    let mut data = Dataset::with_capacity(300, 3);
    for i in 0..300 {
        let base = if i % 2 == 0 { -8.0 } else { 8.0 };
        let p: Vec<f32> = (0..3).map(|_| base + rng.normal() as f32).collect();
        data.push(&p);
    }
    let global = WeightedSet::unit(data.clone());
    let g = generators::path(3);
    let locals: Vec<WeightedSet> = (0..3)
        .map(|i| {
            WeightedSet::unit(data.gather(&(i * 100..(i + 1) * 100).collect::<Vec<_>>()))
        })
        .collect();
    let run = cluster_on_graph(
        &g,
        &locals,
        &DistributedConfig {
            t: 2_000,
            k: 2,
            ..Default::default()
        },
        &RustBackend,
        &mut rng,
    )
    .unwrap();
    let direct = approx_solution(&global, 2, Objective::KMeans, &RustBackend, &mut rng, 30);
    let ratio =
        distclus::clustering::cost_of(&global, &run.centers, Objective::KMeans) / direct.cost;
    assert!(ratio < 1.05, "ratio {ratio}");
}

#[test]
fn one_dimensional_data() {
    let mut rng = Pcg64::seed_from(7);
    let mut data = Dataset::with_capacity(600, 1);
    for i in 0..600 {
        let base = [0.0f32, 10.0, 20.0][i % 3];
        data.push(&[base + rng.normal() as f32 * 0.1]);
    }
    let g = generators::grid(2, 3);
    let mut r2 = Pcg64::seed_from(8);
    let locals: Vec<WeightedSet> = distclus::partition::Scheme::Uniform
        .partition(&data, 6, &mut r2)
        .unwrap()
        .into_iter()
        .map(WeightedSet::unit)
        .collect();
    let run = cluster_on_graph(
        &g,
        &locals,
        &DistributedConfig {
            t: 120,
            k: 3,
            ..Default::default()
        },
        &RustBackend,
        &mut r2,
    )
    .unwrap();
    // Centers near 0/10/20.
    let mut cs: Vec<f32> = (0..3).map(|c| run.centers.row(c)[0]).collect();
    cs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    assert!((cs[0] - 0.0).abs() < 1.0, "{cs:?}");
    assert!((cs[1] - 10.0).abs() < 1.0, "{cs:?}");
    assert!((cs[2] - 20.0).abs() < 1.0, "{cs:?}");
}

#[test]
fn klines_pipeline_end_to_end() {
    // The k-line extension through the distributed construction.
    use distclus::coreset::klines::{build_portions, KLinesConfig};
    let mut rng = Pcg64::seed_from(9);
    let mut data = Dataset::with_capacity(2_000, 2);
    for i in 0..2_000 {
        let t = 8.0 * (rng.uniform() as f32 - 0.5);
        let p = if i % 2 == 0 {
            [t, 0.1 * rng.normal() as f32]
        } else {
            [0.1 * rng.normal() as f32 + 10.0, t]
        };
        data.push(&p);
    }
    let locals: Vec<WeightedSet> = (0..4)
        .map(|i| {
            WeightedSet::unit(data.gather(&(i * 500..(i + 1) * 500).collect::<Vec<_>>()))
        })
        .collect();
    let portions = build_portions(
        &locals,
        &KLinesConfig {
            t: 400,
            k: 2,
            ..Default::default()
        },
        &mut rng,
    );
    let coreset = distclus::coreset::distributed::union(&portions);
    assert!(coreset.size() <= 400 + 4 * 2 * 8 + 8);
    let ratio = coreset.set.total_weight() / 2_000.0;
    assert!((ratio - 1.0).abs() < 0.2, "mass {ratio}");
}
