//! Churn-determinism suite for the always-on clustering service: the
//! same graph, seed and churn schedule must produce bit-identical
//! coresets, reports and meters at any thread count; the empty schedule
//! must reproduce a plain `StreamingCoordinator` exactly; a collector
//! killed mid-stream and restored from its checkpoint must continue
//! bit-identically; and a failover re-merge must bill strictly below a
//! full portion reflood.

use distclus::clustering::backend::RustBackend;
use distclus::coordinator::streaming::StreamingCoordinator;
use distclus::coreset::DistributedConfig;
use distclus::data::synthetic::gaussian_mixture;
use distclus::exec::ExecPolicy;
use distclus::rng::Pcg64;
use distclus::service::{ChurnSchedule, ClusterService, ServiceEpochReport};
use distclus::topology::generators;
use distclus::trace::{keys, TraceEvent, Tracer};

fn cfg() -> DistributedConfig {
    DistributedConfig {
        t: 120,
        k: 3,
        ..Default::default()
    }
}

/// One scripted event of every kind. With the huge drift threshold the
/// coordinator skips every epoch after the forced ones, so the
/// relay-fail at epoch 3 exercises the failover re-merge and the drop
/// at epoch 4 the portion excision.
const SCHEDULE: &str = "2:leave:2;3:relay-fail;4:drop:8;5:restart;6:join";

fn drive_churny(
    threads: usize,
    tracer: Option<Tracer>,
) -> (Vec<ServiceEpochReport>, ClusterService) {
    let mut svc = ClusterService::new(generators::grid(3, 3), 4, cfg(), 1e9, 42)
        .with_schedule(ChurnSchedule::parse(SCHEDULE).unwrap())
        .with_exec(ExecPolicy::parallel(threads));
    if let Some(t) = tracer {
        svc = svc.with_tracer(t);
    }
    let mut feed = Pcg64::seed_from(1234);
    let mut reports = Vec::new();
    for _ in 0..7 {
        for site in 0..9 {
            if svc.overlay().is_live(site) {
                svc.ingest(site, &gaussian_mixture(&mut feed, 60, 4, 3));
            }
        }
        reports.push(svc.epoch(&RustBackend));
    }
    (reports, svc)
}

#[test]
fn same_seed_and_schedule_is_bit_identical_across_thread_counts() {
    let (base, base_svc) = drive_churny(1, None);
    let base_set = base_svc.coreset().unwrap().set.clone();
    for threads in [2, 8] {
        let (reports, svc) = drive_churny(threads, None);
        assert_eq!(reports, base, "{threads} worker threads diverged");
        assert_eq!(
            svc.coreset().unwrap().set,
            base_set,
            "{threads}-thread coreset differs bitwise"
        );
        assert_eq!(svc.meters(), base_svc.meters());
    }
    // The scripted epochs did what the schedule says.
    assert!(base[0].report.rebuilt, "first epoch builds");
    assert_eq!(base[1].left, vec![2], "graceful leave drains site 2");
    assert!(base[1].report.rebuilt, "a drain forces the rebuild");
    assert!(!base[2].report.rebuilt, "relay failure hits a skip epoch");
    assert!(base[2].recovery_comm_points > 0, "subtree re-merge ran");
    assert_eq!(base[3].left, vec![8], "abrupt drop detaches site 8");
    assert!(base[4].restarted, "scripted checkpoint restart");
    assert_eq!(base[5].joined.len(), 1, "join revives a dead slot");
    // A skip epoch bills exactly one scalar per live ingested site.
    assert_eq!(base[4].report.comm_points, 6);
}

#[test]
fn tracing_never_changes_results_and_records_churn() {
    let (plain, plain_svc) = drive_churny(1, None);
    let tracer = Tracer::new();
    let (traced, traced_svc) = drive_churny(1, Some(tracer.clone()));
    assert_eq!(traced, plain, "tracing changed the run");
    assert_eq!(traced_svc.coreset().unwrap().set, plain_svc.coreset().unwrap().set);
    let log = tracer.snapshot();
    let count = |pred: &dyn Fn(&TraceEvent) -> bool| log.events.iter().filter(|e| pred(e)).count();
    assert_eq!(count(&|e| matches!(e, TraceEvent::Join { .. })), 1);
    assert!(count(&|e| matches!(e, TraceEvent::Leave { graceful: true, .. })) >= 1);
    assert!(count(&|e| matches!(e, TraceEvent::Leave { graceful: false, .. })) >= 2);
    assert_eq!(count(&|e| matches!(e, TraceEvent::RelayFail { .. })), 1);
    assert!(count(&|e| matches!(e, TraceEvent::Recover { .. })) >= 1);
    // The restart drill logs the serialized byte count, then a
    // zero-byte marker from the restored twin.
    let ckpt: Vec<usize> = log
        .events
        .iter()
        .filter_map(|e| match e {
            TraceEvent::Checkpoint { bytes, .. } => Some(*bytes),
            _ => None,
        })
        .collect();
    assert_eq!(ckpt.len(), 2);
    assert!(ckpt[0] > 0 && ckpt[1] == 0);
}

#[test]
fn empty_schedule_reproduces_the_plain_coordinator() {
    let mut svc = ClusterService::new(generators::grid(3, 3), 4, cfg(), 0.3, 7);
    let mut coord = StreamingCoordinator::new(9, 4, cfg(), 0.3).with_retained_portions();
    let mut rng = Pcg64::seed_from(7);
    let mut feed_a = Pcg64::seed_from(55);
    let mut feed_b = Pcg64::seed_from(55);
    for _ in 0..4 {
        for site in 0..9 {
            svc.ingest(site, &gaussian_mixture(&mut feed_a, 50, 4, 3));
            coord.ingest(site, &gaussian_mixture(&mut feed_b, 50, 4, 3));
        }
        let rs = svc.epoch(&RustBackend);
        let rc = coord.epoch(&RustBackend, &mut rng);
        assert_eq!(rs.report, rc, "service epoch drifted from the coordinator");
        assert!(rs.joined.is_empty() && rs.left.is_empty() && !rs.restarted);
        assert_eq!(rs.recovery_comm_points, 0);
    }
    assert_eq!(svc.coreset().unwrap().set, coord.coreset().unwrap().set);
    assert_eq!(svc.coreset().unwrap().sampled, coord.coreset().unwrap().sampled);
}

#[test]
fn failover_re_merge_bills_strictly_below_a_full_rebuild() {
    // One relay failure per epoch on a 3x3 grid; the huge threshold
    // keeps every post-build epoch a skip, so each failure must recover
    // through the subtree re-merge, never a reflood.
    let mut svc = ClusterService::new(generators::grid(3, 3), 4, cfg(), 1e9, 11)
        .with_schedule(ChurnSchedule::parse("2:relay-fail;3:relay-fail;4:relay-fail").unwrap());
    let mut feed = Pcg64::seed_from(21);
    let mut recoveries = 0;
    for epoch in 1..=5usize {
        for site in 0..9 {
            if svc.overlay().is_live(site) {
                svc.ingest(site, &gaussian_mixture(&mut feed, 60, 4, 3));
            }
        }
        let r = svc.epoch(&RustBackend);
        match epoch {
            1 => assert!(r.report.rebuilt, "first epoch builds"),
            2..=4 => {
                assert!(!r.report.rebuilt, "epoch {epoch} must skip");
                assert_eq!(r.relay_failures.len(), 1);
                if r.recovery_comm_points > 0 {
                    assert!(
                        r.recovery_comm_points < r.rebuild_bill,
                        "epoch {epoch}: recovery {} must undercut reflood {}",
                        r.recovery_comm_points,
                        r.rebuild_bill
                    );
                    assert!(r.recovery_rounds > 0, "recovery rounds are metered");
                    recoveries += 1;
                }
            }
            _ => {
                // Quiet skip epoch: exactly one scalar per live site.
                assert_eq!(r.report.comm_points, svc.n_live());
            }
        }
    }
    assert!(recoveries >= 2, "expected re-merges, got {recoveries}");
    let meters = svc.meters();
    assert_eq!(meters[keys::RELAY_FAILURES], 3);
    assert!(meters[keys::RECOVERY_ROUNDS] > 0);
    assert!(meters[keys::EPOCH_ROUNDS_P99] > 0);
}

#[test]
fn checkpoint_restore_mid_stream_is_bit_identical() {
    let schedule = "2:relay-fail;4:drop:2;5:restart;6:join";
    let mut svc = ClusterService::new(generators::grid(3, 3), 4, cfg(), 0.3, 17)
        .with_schedule(ChurnSchedule::parse(schedule).unwrap());
    let mut feed = Pcg64::seed_from(9);
    for _ in 0..3 {
        for site in 0..9 {
            if svc.overlay().is_live(site) {
                svc.ingest(site, &gaussian_mixture(&mut feed, 50, 4, 3));
            }
        }
        svc.epoch(&RustBackend);
    }
    // Kill the collector: all that survives is the serialized text.
    let text = svc.checkpoint().to_string();
    let mut twin = ClusterService::restore(&distclus::json::parse(&text).unwrap()).unwrap();
    // Both continue on identical feeds through more scripted churn.
    let mut feed_a = Pcg64::seed_from(99);
    let mut feed_b = Pcg64::seed_from(99);
    for _ in 0..3 {
        for site in 0..9 {
            if svc.overlay().is_live(site) {
                svc.ingest(site, &gaussian_mixture(&mut feed_a, 50, 4, 3));
            }
            if twin.overlay().is_live(site) {
                twin.ingest(site, &gaussian_mixture(&mut feed_b, 50, 4, 3));
            }
        }
        let ra = svc.epoch(&RustBackend);
        let rb = twin.epoch(&RustBackend);
        assert_eq!(ra, rb, "restored collector diverged");
    }
    assert_eq!(svc.coreset().unwrap().set, twin.coreset().unwrap().set);
    assert_eq!(svc.meters(), twin.meters());
    assert_eq!(svc.checkpoint().to_string(), twin.checkpoint().to_string());
}

#[test]
fn every_service_meter_is_registered() {
    // Registry drift guard: `meters()` may only emit keys that
    // `trace::keys::ALL` documents, so report emitters that iterate the
    // registry never silently drop a service meter (and pallas-lint's
    // static meter-registry-sync check stays in sync with runtime).
    let (_, svc) = drive_churny(1, None);
    let meters = svc.meters();
    assert!(!meters.is_empty(), "the driven service must report meters");
    for key in meters.keys() {
        assert!(
            keys::ALL.iter().any(|(k, _)| k == key),
            "service meter `{key}` is not in the trace::keys registry"
        );
    }
}
