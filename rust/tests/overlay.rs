//! The overlay-reduced graph exchange — contracts of the PR 5 tentpole:
//!
//! 1. acceptance: on a 16-node connected Erdős–Rényi graph at t = 2048,
//!    the overlay's *total* wire points land strictly below flooded
//!    graph mode's `2m(t + nk)` portion bound at identical seeds, with
//!    solution cost within the run's reported composed error factor of
//!    the flooded solution;
//! 2. the overlay has no channel of its own: every overlay hop pays the
//!    underlying *graph* edge's per-edge `LinkModel` capacity;
//! 3. zero-point sites: a site whose portion paginates to a single
//!    zero-cost empty page still counts toward `sites_expected` at
//!    folding relays and toward overlay root completion (mixed
//!    empty/non-empty runs on both the tree and overlay paths);
//! 4. error accounting: the composed factor is monotone as the overlay
//!    deepens on a path graph (the algebraic half is the unit property
//!    in `protocol/distributed_clustering.rs`), and exactly 1.0 under
//!    `--sketch exact` everywhere exact is legal;
//! 5. axis validation: overlay × exact, overlay × monolithic paging and
//!    overlay × tree-only algorithms are rejected loudly.

use distclus::clustering::backend::RustBackend;
use distclus::clustering::Objective;
use distclus::coreset::zhang::ZhangConfig;
use distclus::coreset::{Coreset, DistributedConfig};
use distclus::network::LinkModel;
use distclus::partition::Scheme;
use distclus::points::WeightedSet;
use distclus::rng::Pcg64;
use distclus::scenario::{BuildCtx, CoresetAlgorithm, Distributed, Exchange, Scenario, Zhang};
use distclus::sketch::SketchPlan;
use distclus::testutil::{mixture_sites, overlay_acceptance};
use distclus::topology::{generators, SpanningTree};
use distclus::trace::keys;

#[test]
fn overlay_wire_total_beats_flooded_2m_bound_on_er16() {
    // The fixture (shared with the comm_scaling panel, so the operating
    // point lives in one place) already asserts the tentpole contract:
    // the overlay's ENTIRE bill — its own cost flood, the converge-
    // folded reduced streams, the reduced-set flood and the centers
    // flood — lands strictly below the flooded portion exchange alone,
    // at solution cost within the overlay's composed error factor.
    let a = overlay_acceptance(12_000);
    let (g, t, k) = (&a.graph, a.t, a.k);
    let n = g.n();

    // Flooding pays exactly 2mn (costs) + 2m(t + nk) (portions).
    assert_eq!(
        a.flooded.comm_points,
        2 * g.m() * n + a.flooded_portion_bound
    );
    assert!(a.overlay.comm_points < a.flooded.comm_points);

    assert_eq!(a.overlay.algorithm, "distributed-coreset (overlay)");
    assert_eq!(a.overlay.sketch, "merge-reduce");
    assert_eq!(a.overlay.centers.n(), k);
    // What flooded back (and what the root solved on) is the REDUCED
    // set, not the full t + nk stream.
    assert!(
        a.overlay.coreset.size() < t + n * k,
        "reduced root set {} !< full stream {}",
        a.overlay.coreset.size(),
        t + n * k
    );
    // Error accounting composes along the overlay chains into the
    // run-level meter.
    assert!(a.overlay.meters.contains_key(keys::MR_REDUCTIONS));
    assert!(a.overlay.error_factor() >= 1.0);
}

#[test]
fn overlay_hops_pay_the_underlying_graph_edge_capacities() {
    // On a path graph every spanning-tree overlay edge IS a graph edge,
    // so throttling one graph edge via a per-edge override (the default
    // stays unlimited) must back-pressure the overlay run: the slow
    // edge carries converge traffic and the reduced-set flood at one
    // point per round, stretching `rounds` well past the open run.
    let n = 6usize;
    let locals = mixture_sites(71, 3_000, 3, 3, n, Scheme::Uniform, false);
    let g = generators::path(n);
    let cfg = DistributedConfig {
        t: 512,
        k: 3,
        ..Default::default()
    };
    let run_with = |link: LinkModel| {
        Scenario::on_overlay_of(g.clone())
            .page_points(16)
            .links(link)
            .sketch(SketchPlan::merge_reduce(128))
            .seed(72)
            .run(&Distributed(cfg), &locals, &RustBackend)
            .unwrap()
    };
    let open = run_with(LinkModel::unlimited());
    let throttled = run_with(LinkModel::unlimited().with_link(2, 3, 1));
    assert!(
        throttled.rounds > open.rounds,
        "a throttled graph edge must stretch the overlay run: {} !> {}",
        throttled.rounds,
        open.rounds
    );
    assert_eq!(open.centers.n(), 3);
    assert_eq!(throttled.centers.n(), 3);
    assert!(open.comm_points > 0 && throttled.comm_points > 0);
}

/// A test-only construction handing the wire phase a fixed set of
/// portions — the only way to drive genuinely empty sites through the
/// public `Scenario` surface (real constructions always append local
/// centers, and the experiment driver patches empty sites up front).
struct FixedPortions {
    k: usize,
    portions: Vec<Coreset>,
}

impl CoresetAlgorithm for FixedPortions {
    fn k(&self) -> usize {
        self.k
    }

    fn objective(&self) -> Objective {
        Objective::KMeans
    }

    fn label(&self, _tree: bool) -> &'static str {
        "fixed-portions"
    }

    fn build(&self, _ctx: BuildCtx<'_, '_>) -> anyhow::Result<Exchange> {
        Ok(Exchange::Portions {
            portions: self.portions.clone(),
            costs: None,
        })
    }
}

/// `sites` portions over a path, the ones named in `empty` zero-point.
fn mixed_portions(seed: u64, sites: usize, d: usize, empty: &[usize]) -> Vec<Coreset> {
    let mut rng = Pcg64::seed_from(seed);
    (0..sites)
        .map(|i| {
            let mut set = WeightedSet::empty(d);
            if !empty.contains(&i) {
                for _ in 0..40 {
                    let p: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
                    set.push(&p, rng.uniform() + 0.1);
                }
            }
            Coreset {
                sampled: set.n(),
                set,
            }
        })
        .collect()
}

#[test]
fn zero_point_sites_complete_tree_and_overlay_folds() {
    // Sites 1 (an interior relay) and 5 (a leaf) paginate to a single
    // zero-cost empty page each. If an empty site failed to count
    // toward `sites_expected` at folding relays (or toward overlay root
    // completion), the session would go quiescent with the collection
    // torn and the run would error out instead of completing.
    let sites = 6usize;
    let empty = [1usize, 5];
    let portions = mixed_portions(81, sites, 3, &empty);
    let live_points: usize = portions.iter().map(|c| c.set.n()).sum();
    let locals: Vec<WeightedSet> = portions.iter().map(|c| c.set.clone()).collect();
    let algo = FixedPortions {
        k: 2,
        portions: portions.clone(),
    };
    let g = generators::path(sites);

    // Tree path: merge-reduce relays complete through empty sites.
    let tree = SpanningTree::bfs(&g, 0);
    let run = Scenario::on_tree(tree)
        .page_points(8)
        .sketch(SketchPlan::merge_reduce(64))
        .seed(82)
        .run(&algo, &locals, &RustBackend)
        .unwrap();
    assert!(run.coreset.size() > 0 && run.coreset.size() <= live_points);
    assert_eq!(run.centers.n(), 2);

    // Exact tree mode for the same mix: byte-compatible union, so the
    // empty sites contribute exactly nothing.
    let tree = SpanningTree::bfs(&g, 0);
    let exact = Scenario::on_tree(tree)
        .page_points(8)
        .seed(83)
        .run(&algo, &locals, &RustBackend)
        .unwrap();
    assert_eq!(exact.coreset.size(), live_points);

    // Overlay path: empty sites count toward relay AND root completion,
    // and every node still receives the reduced root set + centers.
    let run = Scenario::on_overlay_of(g.clone())
        .page_points(8)
        .sketch(SketchPlan::merge_reduce(64))
        .seed(84)
        .run(&algo, &locals, &RustBackend)
        .unwrap();
    assert!(run.coreset.size() > 0 && run.coreset.size() <= live_points);
    assert_eq!(run.centers.n(), 2);
    assert_eq!(run.algorithm, "fixed-portions");

    // Degenerate extreme: every site empty except one, empty at both
    // ends of the path (root side and leaf side).
    let portions = mixed_portions(85, sites, 3, &[0, 1, 3, 4, 5]);
    let locals: Vec<WeightedSet> = portions.iter().map(|c| c.set.clone()).collect();
    let algo = FixedPortions { k: 1, portions };
    let run = Scenario::on_overlay_of(g)
        .page_points(8)
        .sketch(SketchPlan::merge_reduce(64))
        .seed(86)
        .run(&algo, &locals, &RustBackend)
        .unwrap();
    assert!(run.coreset.size() > 0);
    assert_eq!(run.centers.n(), 1);
}

#[test]
fn overlay_error_factor_grows_with_depth_and_exact_is_one() {
    // End-to-end half of the worst-chain contract: identical data at
    // every site, so a longer path means strictly more reducing relays
    // between the far leaf and the root — the measured composed factor
    // must not shrink as the overlay deepens (the algebraic guarantee —
    // chain products of factors ≥ 1 are monotone in depth — is pinned
    // by the unit property test next to `composed_error_factor`).
    let site = mixture_sites(61, 600, 3, 3, 1, Scheme::Uniform, false)
        .pop()
        .unwrap();
    let cfg = DistributedConfig {
        t: 256,
        k: 2,
        ..Default::default()
    };
    let factor_at = |len: usize| {
        let locals = vec![site.clone(); len];
        Scenario::on_overlay_of(generators::path(len))
            .page_points(16)
            .sketch(SketchPlan::merge_reduce(64))
            .seed(62)
            .run(&Distributed(cfg), &locals, &RustBackend)
            .unwrap()
            .error_factor()
    };
    let shallow = factor_at(2);
    let deep = factor_at(16);
    assert!(shallow >= 1.0);
    assert!(
        deep > 1.0,
        "a 16-deep overlay of 600-point sites must register reductions"
    );
    assert!(
        deep >= shallow,
        "composed factor must not shrink with depth: {deep} < {shallow}"
    );

    // Exact folding is lossless wherever it is legal: factor exactly 1.
    let locals = mixture_sites(63, 2_000, 3, 3, 5, Scheme::Uniform, false);
    let g = generators::star(5);
    let graph_exact = Scenario::on_graph(g.clone())
        .seed(64)
        .run(&Distributed(cfg), &locals, &RustBackend)
        .unwrap();
    assert_eq!(graph_exact.error_factor(), 1.0);
    let tree_exact = Scenario::on_tree(SpanningTree::bfs(&g, 0))
        .seed(65)
        .run(&Distributed(cfg), &locals, &RustBackend)
        .unwrap();
    assert_eq!(tree_exact.error_factor(), 1.0);
    let stree_exact = Scenario::on_spanning_tree_of(g)
        .seed(66)
        .run(&Distributed(cfg), &locals, &RustBackend)
        .unwrap();
    assert_eq!(stree_exact.error_factor(), 1.0);
}

#[test]
fn overlay_axis_misconfigs_are_rejected_loudly() {
    let locals = mixture_sites(51, 1_000, 3, 3, 4, Scheme::Uniform, false);
    let g = generators::star(4);
    let cfg = DistributedConfig {
        t: 128,
        k: 2,
        ..Default::default()
    };

    // Overlay × exact sketch: nothing to reduce — rejected.
    let err = Scenario::on_overlay_of(g.clone())
        .page_points(16)
        .run(&Distributed(cfg), &locals, &RustBackend)
        .unwrap_err();
    assert!(err.to_string().contains("merge-reduce"), "{err}");

    // Overlay × monolithic paging (page_points = 0): rejected.
    let err = Scenario::on_overlay_of(g.clone())
        .sketch(SketchPlan::merge_reduce(64))
        .run(&Distributed(cfg), &locals, &RustBackend)
        .unwrap_err();
    assert!(err.to_string().contains("page-points"), "{err}");

    // Overlay × a tree-only algorithm: rejected before any compute
    // (zhang trips its sketch-axis rejection first — it supports
    // neither the fold nor a graph-mode exchange, and either way the
    // run must name the offending algorithm loudly).
    let err = Scenario::on_overlay_of(g)
        .page_points(16)
        .sketch(SketchPlan::merge_reduce(64))
        .run(
            &Zhang(ZhangConfig {
                t_node: 32,
                k: 2,
                objective: Objective::KMeans,
            }),
            &locals,
            &RustBackend,
        )
        .unwrap_err();
    assert!(err.to_string().contains("zhang"), "{err}");
}
