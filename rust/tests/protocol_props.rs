//! Property tests for the protocols (Algorithms 2–3, Theorems 2–3):
//! exact communication accounting and delivery guarantees on arbitrary
//! connected topologies.

use distclus::network::{Network, Payload};
use distclus::points::{Dataset, WeightedSet};
use distclus::prop_assert;
use distclus::protocol::{broadcast_down, converge_cast, flood};
use distclus::rng::Pcg64;
use distclus::testutil::{arb_connected_graph, for_all};
use distclus::topology::{connected, diameter, Graph, SpanningTree};

#[test]
fn prop_flooding_delivers_everything_at_exact_cost() {
    for_all(
        30,
        11,
        |rng| {
            let g = arb_connected_graph(rng, 24);
            // Mixed payload sizes: scalars and point sets.
            let sizes: Vec<usize> = (0..g.n()).map(|_| 1 + rng.below(7)).collect();
            (g, sizes)
        },
        |(g, sizes)| {
            let payloads: Vec<Payload> = sizes
                .iter()
                .enumerate()
                .map(|(i, &s)| {
                    if s == 1 {
                        Payload::LocalCost {
                            site: i,
                            cost: 1.0,
                        }
                    } else {
                        Payload::PortionPage {
                            site: i,
                            page: 0,
                            pages: 1,
                            set: std::sync::Arc::new(WeightedSet::unit(
                                Dataset::from_flat(vec![0.0; s * 2], 2),
                            )),
                        }
                    }
                })
                .collect();
            let total_size: usize = payloads.iter().map(|p| p.size_points()).sum();
            let mut net = Network::new(g.clone());
            let held = flood(&mut net, payloads);
            // Delivery: every node holds every payload.
            for (v, h) in held.iter().enumerate() {
                prop_assert!(h.len() == g.n(), "node {v} missing payloads");
            }
            // Exact Theorem-2 accounting: each node forwards each payload
            // to all neighbors exactly once.
            prop_assert!(
                net.cost_points() == 2 * g.m() * total_size,
                "cost {} != 2*{}*{}",
                net.cost_points(),
                g.m(),
                total_size
            );
            // Round bound: BFS propagation terminates within diam + 2.
            prop_assert!(
                net.round() <= diameter(g) + 2,
                "rounds {} vs diameter {}",
                net.round(),
                diameter(g)
            );
            Ok(())
        },
    );
}

#[test]
fn prop_tree_convergecast_cost_is_sum_of_depths() {
    for_all(
        30,
        22,
        |rng| {
            let g = arb_connected_graph(rng, 24);
            let root = rng.below(g.n());
            (g, root)
        },
        |(g, root)| {
            let tree = SpanningTree::bfs(g, *root);
            let payloads: Vec<Payload> = (0..g.n())
                .map(|i| Payload::LocalCost {
                    site: i,
                    cost: 0.0,
                })
                .collect();
            let mut net = Network::new(tree.as_graph());
            let collected = converge_cast(&mut net, &tree, payloads);
            prop_assert!(collected.len() == g.n(), "root missing payloads");
            let expect: usize = (0..g.n()).map(|v| tree.depth[v]).sum();
            prop_assert!(
                net.cost_points() == expect,
                "cost {} != Σdepth {}",
                net.cost_points(),
                expect
            );
            prop_assert!(
                net.cost_points() <= g.n() * tree.height().max(1),
                "Theorem 3 bound violated"
            );
            Ok(())
        },
    );
}

#[test]
fn prop_broadcast_charges_each_edge_once() {
    for_all(
        30,
        33,
        |rng| {
            let g = arb_connected_graph(rng, 24);
            let root = rng.below(g.n());
            (g, root)
        },
        |(g, root)| {
            let tree = SpanningTree::bfs(g, *root);
            let mut net = Network::new(tree.as_graph());
            broadcast_down(&mut net, &tree, &Payload::Scalar(1.0));
            prop_assert!(
                net.cost_points() == g.n() - 1,
                "broadcast cost {} != n-1 = {}",
                net.cost_points(),
                g.n() - 1
            );
            Ok(())
        },
    );
}

#[test]
fn prop_spanning_tree_is_spanning_and_minimal_depth() {
    for_all(
        40,
        44,
        |rng| {
            let g = arb_connected_graph(rng, 30);
            let root = rng.below(g.n());
            (g, root)
        },
        |(g, root)| {
            let tree = SpanningTree::bfs(g, *root);
            let tg: Graph = tree.as_graph();
            prop_assert!(tg.m() == g.n() - 1, "not a tree: {} edges", tg.m());
            prop_assert!(connected(&tg), "tree disconnected");
            // BFS trees give shortest-path depths.
            let dist = distclus::topology::bfs_distances(g, *root);
            for v in 0..g.n() {
                prop_assert!(
                    tree.depth[v] == dist[v],
                    "depth[{v}]={} != bfs dist {}",
                    tree.depth[v],
                    dist[v]
                );
            }
            Ok(())
        },
    );
}

#[test]
fn prop_partitions_conserve_points_on_arbitrary_graphs() {
    use distclus::partition::Scheme;
    for_all(
        20,
        55,
        |rng| {
            let g = arb_connected_graph(rng, 16);
            let data = distclus::testutil::arb_dataset(rng, 1_500, 8);
            let scheme = [
                Scheme::Uniform,
                Scheme::Similarity,
                Scheme::Weighted,
                Scheme::Degree,
            ][rng.below(4)];
            let seed = rng.next_u64();
            (g, data, scheme, seed)
        },
        |(g, data, scheme, seed)| {
            let mut rng = Pcg64::seed_from(*seed);
            let parts = scheme.partition_on(data, g, &mut rng);
            prop_assert!(parts.len() == g.n(), "wrong number of sites");
            let total: usize = parts.iter().map(|p| p.n()).sum();
            prop_assert!(total == data.n(), "lost points: {total} != {}", data.n());
            for p in parts {
                prop_assert!(p.d == data.d, "dimension drift");
            }
            Ok(())
        },
    );
}
