//! Scenario-builder API — the typed run surface's contracts:
//!
//! 1. builder-vs-legacy bit-compatibility: every legacy entry point is
//!    a shim over [`Scenario`], so centers, coreset, communication,
//!    rounds and all peak meters must agree exactly, for all five
//!    algorithm variants across 1/2/8 worker threads;
//! 2. the per-directed-edge [`LinkModel`] axis: throttling one edge of
//!    a star stretches `rounds` while total communication and results
//!    stay bit-identical (property test — the acceptance criterion of
//!    the heterogeneous-links axis);
//! 3. error-accounted merge-reduce: the composed `(1+ε)^levels` meter
//!    registers reductions and stays 1.0 on exact runs;
//! 4. composed exchanges (Zhang) accept the channel axis — and stay
//!    bit-identical under it, because one summary per edge can never
//!    saturate a link.

use distclus::clustering::backend::RustBackend;
use distclus::clustering::Objective;
use distclus::coreset::combine::CombineConfig;
use distclus::coreset::zhang::ZhangConfig;
use distclus::coreset::DistributedConfig;
use distclus::exec::ExecPolicy;
use distclus::network::{ChannelConfig, LinkModel};
use distclus::partition::Scheme;
use distclus::points::WeightedSet;
use distclus::prop_assert;
use distclus::protocol::{
    cluster_on_graph_exec, cluster_on_tree_exec, combine_on_graph, combine_on_tree,
    zhang_on_tree_exec, RunResult,
};
use distclus::rng::Pcg64;
use distclus::scenario::{Combine, Distributed, Scenario, Zhang};
use distclus::sketch::SketchPlan;
use distclus::testutil::{for_all, mixture_sites};
use distclus::topology::{generators, Graph, SpanningTree};
use distclus::trace::keys;

fn assert_bit_identical(a: &RunResult, b: &RunResult, what: &str) {
    assert_eq!(a.centers, b.centers, "{what}: centers");
    assert_eq!(a.coreset.set, b.coreset.set, "{what}: coreset");
    assert_eq!(a.comm_points, b.comm_points, "{what}: comm");
    assert_eq!(a.rounds, b.rounds, "{what}: rounds");
    assert_eq!(a.peak_points, b.peak_points, "{what}: wire peak");
    assert_eq!(a.node_peaks, b.node_peaks, "{what}: node peaks");
    assert_eq!(a.collector_peak, b.collector_peak, "{what}: collector peak");
    assert_eq!(a.algorithm, b.algorithm, "{what}: label");
}

fn fixture(seed: u64, sites: usize) -> (Graph, SpanningTree, Vec<WeightedSet>) {
    let locals = mixture_sites(seed, 4_000, 5, 4, sites, Scheme::Weighted, true);
    let mut rng = Pcg64::seed_from(seed ^ 0xABCD);
    let g = generators::erdos_renyi_connected(&mut rng, locals.len(), 0.4);
    let tree = SpanningTree::bfs(&g, 0);
    (g, tree, locals)
}

#[test]
fn builder_matches_legacy_for_all_five_algorithms() {
    let (g, tree, locals) = fixture(11, 8);
    let dcfg = DistributedConfig {
        t: 400,
        k: 4,
        ..Default::default()
    };
    let ccfg = CombineConfig {
        t: 400,
        k: 4,
        objective: Objective::KMeans,
    };
    let zcfg = ZhangConfig {
        t_node: 60,
        k: 4,
        objective: Objective::KMeans,
    };

    // Exec-capable legacy entries × 1/2/8 worker threads.
    for threads in [1usize, 2, 8] {
        let exec = ExecPolicy::parallel(threads);
        let what = format!("distributed/graph t={threads}");
        let mut rng = Pcg64::seed_from(7);
        let legacy =
            cluster_on_graph_exec(&g, &locals, &dcfg, &RustBackend, &mut rng, exec).unwrap();
        let built = Scenario::on_graph(g.clone())
            .exec(exec)
            .seed(7)
            .run(&Distributed(dcfg), &locals, &RustBackend)
            .unwrap();
        assert_bit_identical(&legacy, &built, &what);

        let what = format!("distributed/tree t={threads}");
        let mut rng = Pcg64::seed_from(8);
        let legacy =
            cluster_on_tree_exec(&tree, &locals, &dcfg, &RustBackend, &mut rng, exec).unwrap();
        let built = Scenario::on_tree(tree.clone())
            .exec(exec)
            .seed(8)
            .run(&Distributed(dcfg), &locals, &RustBackend)
            .unwrap();
        assert_bit_identical(&legacy, &built, &what);

        let what = format!("zhang/tree t={threads}");
        let mut rng = Pcg64::seed_from(9);
        let legacy =
            zhang_on_tree_exec(&tree, &locals, &zcfg, &RustBackend, &mut rng, exec).unwrap();
        let built = Scenario::on_tree(tree.clone())
            .exec(exec)
            .seed(9)
            .run(&Zhang(zcfg), &locals, &RustBackend)
            .unwrap();
        assert_bit_identical(&legacy, &built, &what);
    }

    // The sequential-only combine entries.
    let mut rng = Pcg64::seed_from(10);
    let legacy = combine_on_graph(&g, &locals, &ccfg, &RustBackend, &mut rng).unwrap();
    let built = Scenario::on_graph(g.clone())
        .seed(10)
        .run(&Combine(ccfg), &locals, &RustBackend)
        .unwrap();
    assert_bit_identical(&legacy, &built, "combine/graph");

    let mut rng = Pcg64::seed_from(12);
    let legacy = combine_on_tree(&tree, &locals, &ccfg, &RustBackend, &mut rng).unwrap();
    let built = Scenario::on_tree(tree.clone())
        .seed(12)
        .run(&Combine(ccfg), &locals, &RustBackend)
        .unwrap();
    assert_bit_identical(&legacy, &built, "combine/tree");

    // Combine gains parallel execution through the builder (no legacy
    // entry to compare against) — results must be thread-invariant.
    let combine_at = |threads: usize| {
        Scenario::on_graph(g.clone())
            .exec(ExecPolicy::parallel(threads))
            .seed(13)
            .run(&Combine(ccfg), &locals, &RustBackend)
            .unwrap()
    };
    assert_bit_identical(&combine_at(2), &combine_at(8), "combine thread-invariance");
}

#[test]
fn prop_throttled_edge_stretches_rounds_at_identical_results() {
    // The per-edge capacity acceptance criterion: a star with ONE
    // throttled link must take strictly more rounds than the uniform
    // star at identical total points and bit-identical centers — the
    // link model reshapes time, never results.
    for_all(
        8,
        97,
        |rng| {
            let t = 256 + rng.below(512);
            let page = 16 + rng.below(33);
            let slow = 2 + rng.below(6);
            (t, page, slow, rng.next_u64())
        },
        |&(t, page, slow, seed)| {
            let locals = mixture_sites(seed, 3_000, 4, 4, 5, Scheme::Uniform, false);
            let g = generators::star(5);
            let cfg = DistributedConfig {
                t,
                k: 4,
                ..Default::default()
            };
            let run_with = |link: LinkModel| {
                Scenario::on_graph(g.clone())
                    .channel(ChannelConfig {
                        page_points: page,
                        link,
                    })
                    .seed(seed ^ 1)
                    .run(&Distributed(cfg), &locals, &RustBackend)
                    .unwrap()
            };
            let uniform = run_with(LinkModel::capped(256));
            let throttled = run_with(LinkModel::capped(256).with_link(1, 0, slow));
            prop_assert!(
                throttled.comm_points == uniform.comm_points,
                "comm changed: {} != {}",
                throttled.comm_points,
                uniform.comm_points
            );
            prop_assert!(
                throttled.centers == uniform.centers,
                "a slow edge must not change the solution"
            );
            prop_assert!(
                throttled.coreset.set == uniform.coreset.set,
                "a slow edge must not change the coreset"
            );
            prop_assert!(
                throttled.rounds > uniform.rounds,
                "throttled rounds {} !> uniform {}",
                throttled.rounds,
                uniform.rounds
            );
            Ok(())
        },
    );
}

#[test]
fn degraded_subset_profile_runs_end_to_end() {
    // The ROADMAP scenario this API unblocks: a grid deployment where a
    // whole subset of links is degraded (asymmetric backhaul).
    let locals = mixture_sites(21, 3_000, 4, 4, 9, Scheme::Uniform, false);
    let g = generators::grid(3, 3);
    let cfg = DistributedConfig {
        t: 512,
        k: 4,
        ..Default::default()
    };
    let run_with = |link: LinkModel| {
        Scenario::on_graph(g.clone())
            .page_points(32)
            .links(link)
            .seed(22)
            .run(&Distributed(cfg), &locals, &RustBackend)
            .unwrap()
    };
    let uniform = run_with(LinkModel::capped(128));
    let degraded = run_with(LinkModel::capped(128).degraded(&[(0, 1), (3, 4)], 4));
    assert_eq!(uniform.comm_points, degraded.comm_points);
    assert_eq!(uniform.centers, degraded.centers);
    assert!(
        degraded.rounds > uniform.rounds,
        "degraded {} !> uniform {}",
        degraded.rounds,
        uniform.rounds
    );
}

#[test]
fn merge_reduce_meters_surface_error_accounting() {
    let locals = mixture_sites(33, 6_000, 4, 4, 5, Scheme::Uniform, false);
    let g = generators::star(5);
    let cfg = DistributedConfig {
        t: 2_048,
        k: 4,
        ..Default::default()
    };
    let base = || {
        Scenario::on_graph(g.clone())
            .channel(ChannelConfig::uniform(64, 64))
            .seed(3)
    };
    let exact = base().run(&Distributed(cfg), &locals, &RustBackend).unwrap();
    assert!(
        exact.meters.keys().all(|m| !m.starts_with("mr_")),
        "exact runs carry no error-accounting meters"
    );
    assert_eq!(exact.error_factor(), 1.0);

    let mr = base()
        .sketch(SketchPlan::merge_reduce(256))
        .run(&Distributed(cfg), &locals, &RustBackend)
        .unwrap();
    assert!(
        mr.meters[keys::MR_REDUCTIONS] > 0,
        "reductions must be counted"
    );
    assert!(
        mr.error_factor() > 1.0,
        "composed factor {} must register measured distortion",
        mr.error_factor()
    );
    assert!(
        mr.error_factor() < 8.0,
        "implausible composed factor {}",
        mr.error_factor()
    );
}

#[test]
fn composed_exchanges_accept_the_channel_axis() {
    // Zhang's summary transfers ran outside any link model before the
    // Scenario redesign; now the channel axis reaches its wire phase
    // too. Its traffic pattern, however, puts exactly ONE summary on
    // each directed edge per session (every node emits once, after its
    // children) — and a lone message always ships on an idle edge (the
    // simulator's progress guarantee) — so a per-round capacity has
    // nothing to defer: every meter must be *identical*, not merely
    // the totals. This pins both the plumbing and the reason the axis
    // cannot bind here.
    let locals = mixture_sites(41, 2_000, 4, 3, 6, Scheme::Uniform, false);
    let tree = SpanningTree::bfs(&generators::path(6), 0);
    let zcfg = ZhangConfig {
        t_node: 48,
        k: 3,
        objective: Objective::KMeans,
    };
    let run_with = |link: LinkModel| {
        Scenario::on_tree(tree.clone())
            .links(link)
            .seed(42)
            .run(&Zhang(zcfg), &locals, &RustBackend)
            .unwrap()
    };
    let open = run_with(LinkModel::unlimited());
    let capped = run_with(LinkModel::capped(8));
    assert_bit_identical(&open, &capped, "zhang under a capacity");
}
