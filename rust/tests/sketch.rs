//! Mergeable-sketch subsystem — property tests and the bounded-memory
//! acceptance criteria of the streaming-solve PR.
//!
//! Pinned here:
//! 1. the merge-and-reduce sketch's resident set stays within
//!    `levels() · bucket_points` for *any* page arrival order, page
//!    size and interleaving (property test);
//! 2. `--sketch exact` is bit-compatible with the materialized
//!    construction — the pipeline's centers and coreset equal the
//!    host-side `build_portions → union → solve` chain at 1/2/8 worker
//!    threads, paged or monolithic;
//! 3. the merge-and-reduce solve stays within 10% of the materialized
//!    solve on the standard mixture workloads (drift test);
//! 4. acceptance (star, `page_points = 64`, `t = 2048`): collector
//!    memory under merge-reduce is strictly below the exact (PR 2)
//!    collector peak and within the levels·bucket bound, at unchanged
//!    wire totals on a graph — while on a tree, in-network reduction
//!    cuts the wire total too.

use distclus::clustering::backend::RustBackend;
use distclus::clustering::{approx_solution, cost_of, Objective};
use distclus::coreset::distributed::{self, DistributedConfig};
use distclus::exec::ExecPolicy;
use distclus::network::{paginate, ChannelConfig, Payload};
use distclus::partition::Scheme;
use distclus::points::WeightedSet;
use distclus::prop_assert;
use distclus::protocol::RunResult;
use distclus::rng::Pcg64;
use distclus::scenario::{Distributed, Scenario};
use distclus::sketch::{MergeReduceSketch, MergeableSketch, SketchPlan};
use distclus::testutil::{arb_portion, for_all, mixture_sites};

#[test]
fn prop_merge_reduce_peak_bounded_under_random_arrival() {
    for_all(
        20,
        81,
        |rng| {
            let sites = 2 + rng.below(5);
            let portions: Vec<_> = (0..sites).map(|_| arb_portion(rng, 600, 3)).collect();
            let bucket = 64 + rng.below(128);
            let page_points = 1 + rng.below(96); // pages may exceed the bucket
            let seed = rng.next_u64();
            (portions, bucket, page_points, seed)
        },
        |(portions, bucket, page_points, seed)| {
            let mut rng = Pcg64::seed_from(*seed);
            let mut pages: Vec<Payload> = portions
                .iter()
                .enumerate()
                .flat_map(|(i, p)| paginate(i, p.clone(), *page_points))
                .collect();
            rng.shuffle(&mut pages);
            let mut sketch = MergeReduceSketch::new(
                *bucket,
                3,
                Objective::KMeans,
                &RustBackend,
                rng.split(),
            );
            for p in &pages {
                if let Payload::PortionPage { site, page, pages, set } = p {
                    sketch.insert_page(*site, *page, *pages, set);
                }
                prop_assert!(
                    sketch.points_held() <= sketch.levels() * sketch.bucket_points(),
                    "held {} > {} levels x {} bucket",
                    sketch.points_held(),
                    sketch.levels(),
                    sketch.bucket_points()
                );
            }
            let bound = sketch.levels() * sketch.bucket_points();
            prop_assert!(
                sketch.peak_points() <= bound,
                "peak {} > bound {}",
                sketch.peak_points(),
                bound
            );
            prop_assert!(
                sketch.complete_sites() == portions.len(),
                "only {} of {} sites complete",
                sketch.complete_sites(),
                portions.len()
            );
            let total_mass: f64 = portions.iter().map(|p| p.total_weight()).sum();
            let out = sketch.finish().map_err(|e| e.to_string())?;
            // Mass sanity only — small buckets compound sampling noise
            // over many levels; the tight mass checks live in the
            // sketch's unit tests at realistic bucket sizes.
            let ratio = out.total_weight() / total_mass;
            prop_assert!(ratio > 0.3 && ratio < 2.0, "mass ratio {ratio}");
            Ok(())
        },
    );
}

fn star_locals(seed: u64, sites: usize, points: usize) -> Vec<WeightedSet> {
    mixture_sites(seed, points, 4, 4, sites, Scheme::Uniform, false)
}

fn run(
    base: Scenario,
    locals: &[WeightedSet],
    cfg: &DistributedConfig,
    channel: ChannelConfig,
    sketch: SketchPlan,
    exec: ExecPolicy,
    seed: u64,
) -> RunResult {
    base.channel(channel)
        .sketch(sketch)
        .exec(exec)
        .seed(seed)
        .run(&Distributed(*cfg), locals, &RustBackend)
        .unwrap()
}

/// The materialized (PR 2) construction, reproduced host-side: round 1,
/// round 2, union, solve — the exact pipeline must match it bit for bit.
fn materialized(
    locals: &[WeightedSet],
    cfg: &DistributedConfig,
    exec: ExecPolicy,
    seed: u64,
) -> (WeightedSet, distclus::points::Dataset) {
    let mut rng = Pcg64::seed_from(seed);
    let portions = distributed::build_portions_exec(locals, cfg, &RustBackend, &mut rng, exec);
    let coreset = distributed::union(&portions);
    let sol = approx_solution(
        &coreset.set,
        cfg.k,
        cfg.objective,
        &RustBackend,
        &mut rng,
        40,
    );
    (coreset.set, sol.centers)
}

#[test]
fn exact_mode_is_bit_identical_to_materialized_construction() {
    // The bit-compatibility contract behind `--sketch exact`: folding
    // pages through the sketch and solving on finish() consumes exactly
    // the RNG draws of the materialized chain, so centers and coreset
    // agree byte for byte — at every worker-thread count, paged or not.
    let locals = star_locals(17, 5, 3_000);
    let g = distclus::topology::generators::star(5);
    let cfg = DistributedConfig {
        t: 512,
        k: 4,
        ..Default::default()
    };
    for threads in [1usize, 2, 8] {
        let exec = ExecPolicy::parallel(threads);
        let (want_set, want_centers) = materialized(&locals, &cfg, exec, 23);
        for channel in [ChannelConfig::default(), ChannelConfig::uniform(64, 64)] {
            let got = run(
                Scenario::on_graph(g.clone()),
                &locals,
                &cfg,
                channel,
                SketchPlan::exact(),
                exec,
                23,
            );
            assert_eq!(got.coreset.set, want_set, "threads={threads}");
            assert_eq!(got.centers, want_centers, "threads={threads}");
        }
    }
}

#[test]
fn merge_reduce_solve_cost_within_ten_percent_of_materialized() {
    // Drift test: folding through the merge-and-reduce tower loses a
    // bounded amount of coreset fidelity per level; at a sane bucket
    // size the final solve must stay within 10% of the materialized
    // solve on the standard mixture workloads.
    for (seed, objective) in [(41u64, Objective::KMeans), (43, Objective::KMedian)] {
        let locals = mixture_sites(seed, 8_000, 6, 4, 5, Scheme::Uniform, false);
        let global = WeightedSet::union(locals.iter());
        let g = distclus::topology::generators::star(5);
        let cfg = DistributedConfig {
            t: 1_024,
            k: 4,
            objective,
            ..Default::default()
        };
        let channel = ChannelConfig::uniform(64, 0);
        let exact = run(
            Scenario::on_graph(g.clone()),
            &locals,
            &cfg,
            channel.clone(),
            SketchPlan::exact(),
            ExecPolicy::Sequential,
            seed + 1,
        );
        let reduced = run(
            Scenario::on_graph(g.clone()),
            &locals,
            &cfg,
            channel,
            SketchPlan::merge_reduce(512),
            ExecPolicy::Sequential,
            seed + 1,
        );
        let c_exact = cost_of(&global, &exact.centers, objective);
        let c_reduced = cost_of(&global, &reduced.centers, objective);
        let drift = (c_reduced - c_exact).abs() / c_exact;
        assert!(
            drift < 0.10,
            "{objective:?}: merge-reduce solve drifted {drift:.3} (exact {c_exact}, reduced {c_reduced})"
        );
    }
}

#[test]
fn acceptance_star_page64_t2048_collector_memory() {
    // The PR acceptance point: star topology, page_points = 64,
    // t = 2048. Exact folding materializes the full t + nk coreset at
    // the collector; the merge-and-reduce sketch must hold strictly
    // less, within its levels·bucket bound, at identical wire totals
    // (a graph sketch is solve-side only) — and exact mode reproduces
    // the materialized centers bit-identically at 1/2/8 threads.
    let locals = star_locals(29, 5, 4_000);
    let g = distclus::topology::generators::star(5);
    let cfg = DistributedConfig {
        t: 2_048,
        k: 4,
        ..Default::default()
    };
    let channel = ChannelConfig::uniform(64, 64);
    let bucket = 256usize;

    let exact = run(
        Scenario::on_graph(g.clone()),
        &locals,
        &cfg,
        channel.clone(),
        SketchPlan::exact(),
        ExecPolicy::Sequential,
        31,
    );
    let reduced = run(
        Scenario::on_graph(g.clone()),
        &locals,
        &cfg,
        channel.clone(),
        SketchPlan::merge_reduce(bucket),
        ExecPolicy::Sequential,
        31,
    );

    // Wire accounting is untouched by the sketch choice on a graph:
    // the exact 2m(n + t + nk) formula in both modes.
    let expected = 2 * g.m() * g.n() + 2 * g.m() * (cfg.t + g.n() * cfg.k);
    assert_eq!(exact.comm_points, expected);
    assert_eq!(reduced.comm_points, expected);

    // Exact materializes the whole coreset at the collector.
    let full = cfg.t + g.n() * cfg.k;
    assert_eq!(exact.collector_peak, full);

    // Merge-reduce: strictly below the PR 2 collector peak, and within
    // the merge-and-reduce memory model — a binary-counter tower over
    // `full / bucket` carries has ~log2 levels plus the accumulator.
    assert!(
        reduced.collector_peak < exact.collector_peak,
        "sketch {} !< materialized {}",
        reduced.collector_peak,
        exact.collector_peak
    );
    let levels = (full as f64 / bucket as f64).log2().ceil() as usize + 2;
    assert!(
        reduced.collector_peak <= levels * bucket,
        "sketch peak {} > {levels} levels x {bucket}",
        reduced.collector_peak
    );
    // Non-collector graph nodes forward and drop in merge-reduce mode,
    // so every node's host buffer obeys the same bound.
    for (v, &peak) in reduced.node_peaks.iter().enumerate() {
        assert!(peak <= levels * bucket, "node {v}: {peak}");
    }

    // Bit-identical exact centers across thread counts, and against the
    // materialized chain.
    let p1 = run(
        Scenario::on_graph(g.clone()),
        &locals,
        &cfg,
        channel.clone(),
        SketchPlan::exact(),
        ExecPolicy::parallel(1),
        31,
    );
    let p2 = run(
        Scenario::on_graph(g.clone()),
        &locals,
        &cfg,
        channel.clone(),
        SketchPlan::exact(),
        ExecPolicy::parallel(2),
        31,
    );
    let p8 = run(
        Scenario::on_graph(g.clone()),
        &locals,
        &cfg,
        channel,
        SketchPlan::exact(),
        ExecPolicy::parallel(8),
        31,
    );
    assert_eq!(p1.centers, p2.centers);
    assert_eq!(p2.centers, p8.centers);
    assert_eq!(p2.coreset.set, p8.coreset.set);
    let (want_set, want_centers) =
        materialized(&locals, &cfg, ExecPolicy::parallel(2), 31);
    assert_eq!(p2.coreset.set, want_set);
    assert_eq!(p2.centers, want_centers);
}

#[test]
fn merge_reduce_tree_reduces_in_network() {
    // Tree converge-cast with in-network reduction: every relay folds
    // its subtree and forwards the reduced stream, so the wire total
    // drops below exact mode and every node's fold stays bounded.
    let locals = mixture_sites(37, 6_000, 4, 4, 9, Scheme::Uniform, false);
    let g = distclus::topology::generators::grid(3, 3);
    let tree = distclus::topology::SpanningTree::bfs(&g, 0);
    let cfg = DistributedConfig {
        t: 2_048,
        k: 4,
        ..Default::default()
    };
    let channel = ChannelConfig::uniform(64, 0);
    let bucket = 256usize;
    let exact = run(
        Scenario::on_tree(tree.clone()),
        &locals,
        &cfg,
        channel.clone(),
        SketchPlan::exact(),
        ExecPolicy::Sequential,
        39,
    );
    let reduced = run(
        Scenario::on_tree(tree.clone()),
        &locals,
        &cfg,
        channel,
        SketchPlan::merge_reduce(bucket),
        ExecPolicy::Sequential,
        39,
    );
    assert!(
        reduced.comm_points < exact.comm_points,
        "in-network reduction must cut traffic: {} !< {}",
        reduced.comm_points,
        exact.comm_points
    );
    assert!(
        reduced.collector_peak < exact.collector_peak,
        "root sketch {} !< materialized {}",
        reduced.collector_peak,
        exact.collector_peak
    );
    let full = cfg.t + g.n() * cfg.k;
    let levels = (full as f64 / bucket as f64).log2().ceil() as usize + 2;
    for (v, &peak) in reduced.node_peaks.iter().enumerate() {
        assert!(
            peak <= levels * bucket,
            "relay {v} peak {peak} > {levels} x {bucket}"
        );
    }
    // Quality stays usable after per-relay recompression.
    let global = WeightedSet::union(locals.iter());
    let c_exact = cost_of(&global, &exact.centers, Objective::KMeans);
    let c_reduced = cost_of(&global, &reduced.centers, Objective::KMeans);
    assert!(
        (c_reduced - c_exact).abs() / c_exact < 0.25,
        "tree drift too large: exact {c_exact}, reduced {c_reduced}"
    );
}
