//! Tracing-layer contracts (the observability tentpole):
//!
//! 1. bit-identity: a traced run is indistinguishable from an untraced
//!    one — centers, coreset, wire totals, rounds, peaks and the
//!    scheduling meters all match exactly — across graph / tree /
//!    overlay topologies × 1 / 2 / 8 worker threads;
//! 2. conservation: the per-edge `Flow` records account for every point
//!    the run charged (`Σ delivered + Σ dropped == comm_points`), the
//!    per-round `Round` records agree with them, and the closing
//!    `Summary` event matches the run's own meters — so a trace file is
//!    self-checking, which `trace_view` and CI grep on;
//! 3. phase spans: the four protocol phases tile the run gaplessly in
//!    protocol order from round 0 (graph mode has no broadcast — all
//!    nodes solve locally — so it records exactly three phases);
//! 4. registry: every meter key a run emits is documented in
//!    `trace::keys::ALL`, and JSONL round-trips a real run's log.

use distclus::clustering::backend::RustBackend;
use distclus::coreset::DistributedConfig;
use distclus::partition::Scheme;
use distclus::protocol::RunResult;
use distclus::rng::Pcg64;
use distclus::scenario::{Distributed, Scenario};
use distclus::sketch::SketchPlan;
use distclus::testutil::mixture_sites;
use distclus::topology::generators;
use distclus::trace::{keys, Phase, TraceEvent, TraceLog};

const KINDS: [&str; 3] = ["graph", "tree", "overlay"];

/// One run of the full pipeline at a fixed operating point: 8-site
/// connected Erdős–Rényi graph, t = 512, k = 3, paged exchange.
fn run_kind(kind: &str, threads: usize, trace: bool) -> RunResult {
    let n = 8usize;
    let locals = mixture_sites(21, 2_400, 4, 4, n, Scheme::Uniform, false);
    let mut rng = Pcg64::seed_from(22);
    let g = generators::erdos_renyi_connected(&mut rng, n, 0.35);
    let cfg = DistributedConfig {
        t: 512,
        k: 3,
        ..Default::default()
    };
    let base = match kind {
        "graph" => Scenario::on_graph(g).page_points(32),
        "tree" => Scenario::on_spanning_tree_of(g).page_points(32),
        "overlay" => Scenario::on_overlay_of(g)
            .page_points(32)
            .sketch(SketchPlan::merge_reduce(128)),
        other => panic!("unknown kind {other}"),
    };
    base.threads(threads)
        .trace(trace)
        .seed(23)
        .run(&Distributed(cfg), &locals, &RustBackend)
        .expect("trace fixture run")
}

#[test]
fn tracing_is_bit_identical_across_topologies_and_threads() {
    for kind in KINDS {
        for threads in [1usize, 2, 8] {
            let off = run_kind(kind, threads, false);
            let on = run_kind(kind, threads, true);
            let tag = format!("{kind}/threads={threads}");
            assert_eq!(on.centers, off.centers, "{tag}: centers");
            assert_eq!(on.coreset.set, off.coreset.set, "{tag}: coreset");
            assert_eq!(on.comm_points, off.comm_points, "{tag}: comm");
            assert_eq!(on.rounds, off.rounds, "{tag}: rounds");
            assert_eq!(on.peak_points, off.peak_points, "{tag}: wire peak");
            assert_eq!(on.collector_peak, off.collector_peak, "{tag}: node peak");
            assert_eq!(
                on.meters[keys::SCHED_TICKS],
                off.meters[keys::SCHED_TICKS],
                "{tag}: sched_ticks"
            );
            assert_eq!(
                on.meters[keys::SCHED_ROUNDS],
                off.meters[keys::SCHED_ROUNDS],
                "{tag}: sched_rounds"
            );
            assert_eq!(
                on.meters[keys::RECV_DRAINS],
                off.meters[keys::RECV_DRAINS],
                "{tag}: recv_drains"
            );
            assert_eq!(
                on.meters[keys::IDLE_RECVS],
                off.meters[keys::IDLE_RECVS],
                "{tag}: idle_recvs"
            );
            // Capture is opt-in: off-runs carry no log and none of the
            // trace-derived meters; on-runs carry both.
            assert!(off.trace.is_none(), "{tag}");
            assert!(!off.meters.contains_key(keys::TRACE_EVENTS), "{tag}");
            assert!(on.trace.is_some(), "{tag}");
            assert!(on.meters[keys::TRACE_EVENTS] > 0, "{tag}");
            assert!(on.meters.contains_key(keys::INFLIGHT_P99), "{tag}");
        }
    }
}

#[test]
fn flow_records_conserve_the_wire_bill() {
    for kind in KINDS {
        let run = run_kind(kind, 1, true);
        let log = run.trace.as_ref().unwrap();

        // Per-edge records account for every charged point (lossless
        // links here, so nothing drops).
        let (delivered, dropped) = log.flow_totals();
        assert_eq!(dropped, 0, "{kind}: lossless run");
        assert_eq!(delivered, run.comm_points, "{kind}: flow vs charge");

        // Per-round totals are the same series, aggregated.
        let per_round: usize = log
            .events
            .iter()
            .filter_map(|ev| match ev {
                TraceEvent::Round {
                    delivered_points, ..
                } => Some(*delivered_points),
                _ => None,
            })
            .sum();
        assert_eq!(per_round, delivered, "{kind}: round records");

        // The closing summary matches the run's own meters.
        let (comm, rounds, summary_dropped) = log.run_summary().unwrap();
        assert_eq!(comm, run.comm_points, "{kind}");
        assert_eq!(rounds, run.rounds, "{kind}");
        assert_eq!(summary_dropped, 0, "{kind}");

        // And the log survives its own wire format.
        let back = TraceLog::from_jsonl(&log.to_jsonl()).unwrap();
        assert_eq!(&back, log, "{kind}: JSONL round-trip");
    }
}

#[test]
fn phase_spans_tile_the_run_in_protocol_order() {
    for kind in KINDS {
        let run = run_kind(kind, 1, true);
        let log = run.trace.as_ref().unwrap();
        let spans = log.phase_spans();

        // Graph mode: every node solves locally on its flooded copy, so
        // there is no broadcast phase at all.
        let expected = if kind == "graph" { 3 } else { 4 };
        assert_eq!(spans.len(), expected, "{kind}: {spans:?}");
        assert_eq!(spans[0].0, Phase::CostFlood, "{kind}");
        assert_eq!(spans[0].1, 0, "{kind}: the cost flood opens the run");
        for w in spans.windows(2) {
            // Protocol order with overlap ≥ 0: each phase starts no
            // later than its predecessor ends (the same readiness flip
            // that exits one phase enters the next).
            assert!(
                w[1].1 <= w[0].2,
                "{kind}: gap between {:?} (ends r{}) and {:?} (starts r{})",
                w[0].0,
                w[0].2,
                w[1].0,
                w[1].1
            );
        }
        let last_end = spans.iter().map(|s| s.2).max().unwrap();
        assert!(
            last_end <= run.rounds as u64,
            "{kind}: span end {last_end} past round count {}",
            run.rounds
        );

        // The derived span meters mirror the spans exactly.
        for &(phase, start, end) in &spans {
            assert_eq!(
                run.meters[phase.meter_key()],
                end - start + 1,
                "{kind}: {phase:?} meter"
            );
        }
        assert_eq!(
            run.meters.contains_key(keys::PHASE_ROUNDS_BROADCAST),
            kind != "graph",
            "{kind}"
        );

        // Fold events appear exactly where a sketch reduces: the
        // merge-reduce overlay registers a fold tree, exact modes none.
        if kind == "overlay" {
            assert!(log.fold_depth() > 0, "overlay must record reductions");
            assert!(run.meters[keys::MR_REDUCTIONS] > 0);
        } else {
            assert_eq!(log.fold_depth(), 0, "{kind}: exact folds are silent");
        }
    }
}

#[test]
fn every_emitted_meter_is_registered() {
    for kind in KINDS {
        let run = run_kind(kind, 1, true);
        for key in run.meters.keys() {
            assert!(
                keys::ALL.iter().any(|&(k, _)| k == *key),
                "{kind}: meter '{key}' missing from the trace::keys registry"
            );
        }
    }
}
