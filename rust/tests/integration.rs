//! End-to-end integration tests: full Algorithm 2 runs over simulated
//! networks, all algorithms, both objectives, config files and the CLI
//! experiment path.

use distclus::clustering::backend::RustBackend;
use distclus::clustering::{approx_solution, cost_of, Objective};
use distclus::config::{Algorithm, ExperimentSpec, TopologySpec};
use distclus::coordinator::{run_experiment, run_once};
use distclus::coreset::DistributedConfig;
use distclus::partition::Scheme;
use distclus::points::WeightedSet;
use distclus::protocol::cluster_on_graph;
use distclus::rng::Pcg64;
use distclus::topology::generators;

fn spec(alg: Algorithm, partition: Scheme) -> ExperimentSpec {
    ExperimentSpec {
        dataset: "synthetic".into(),
        scale: 0.03,
        topology: TopologySpec::Random { n: 8, p: 0.35 },
        partition,
        algorithm: alg,
        k: 5,
        t: 400,
        objective: Objective::KMeans,
        reps: 2,
        seed: 7,
        ..Default::default()
    }
}

#[test]
fn full_pipeline_all_algorithms_all_partitions() {
    for alg in [
        Algorithm::Distributed,
        Algorithm::DistributedTree,
        Algorithm::Combine,
        Algorithm::CombineTree,
        Algorithm::ZhangTree,
    ] {
        for part in [Scheme::Uniform, Scheme::Weighted, Scheme::Degree] {
            let res = run_experiment(&spec(alg, part), &RustBackend).unwrap();
            assert!(
                res.ratio.mean > 0.8 && res.ratio.mean < 2.5,
                "{alg:?}/{part:?}: ratio {}",
                res.ratio.mean
            );
        }
    }
}

#[test]
fn kmedian_objective_end_to_end() {
    let mut s = spec(Algorithm::Distributed, Scheme::Weighted);
    s.objective = Objective::KMedian;
    let res = run_experiment(&s, &RustBackend).unwrap();
    assert!(
        res.ratio.mean > 0.8 && res.ratio.mean < 2.0,
        "kmedian ratio {}",
        res.ratio.mean
    );
}

#[test]
fn all_dataset_analogs_generate_and_cluster() {
    let backend = RustBackend;
    for ds in distclus::data::SPECS {
        let mut rng = Pcg64::seed_from(3);
        // Tiny slice of each dataset, just to prove the path works.
        let scale = (2_000.0 / ds.n as f64).min(1.0);
        let data = ds.generate(&mut rng, scale);
        assert_eq!(data.d, ds.d, "{}", ds.name);
        let set = WeightedSet::unit(data);
        let sol = approx_solution(&set, ds.k.min(8), Objective::KMeans, &backend, &mut rng, 5);
        assert!(sol.cost.is_finite() && sol.cost > 0.0, "{}", ds.name);
    }
}

#[test]
fn cost_ratio_close_to_one_with_generous_budget() {
    // With a large coreset the distributed solution should be
    // near-indistinguishable from the centralized one.
    let mut rng = Pcg64::seed_from(11);
    let data = distclus::data::synthetic::gaussian_mixture(&mut rng, 6_000, 8, 5);
    let g = generators::grid(3, 3);
    let locals: Vec<WeightedSet> = Scheme::Uniform
        .partition_on(&data, &g, &mut rng)
        .into_iter()
        .map(WeightedSet::unit)
        .collect();
    let global = WeightedSet::unit(data);
    let run = cluster_on_graph(
        &g,
        &locals,
        &DistributedConfig {
            t: 3_000,
            k: 5,
            ..Default::default()
        },
        &RustBackend,
        &mut rng,
    )
    .unwrap();
    let direct = approx_solution(&global, 5, Objective::KMeans, &RustBackend, &mut rng, 40);
    let ratio = cost_of(&global, &run.centers, Objective::KMeans) / direct.cost;
    assert!(ratio < 1.05, "ratio {ratio}");
}

#[test]
fn config_file_round_trip_through_runner() {
    let text = "dataset = synthetic\nscale = 0.02\ntopology = grid\nrows = 2\ncols = 3\n\
                partition = similarity\nalgorithm = combine-tree\nt = 200\nreps = 1\nseed = 5\n";
    let spec = ExperimentSpec::from_config(text).unwrap();
    let res = run_experiment(&spec, &RustBackend).unwrap();
    assert!(res.ratio.mean.is_finite());
    assert_eq!(res.label, "synthetic/grid-similarity/combine-tree");
}

#[test]
fn run_once_exposes_coreset_and_comm() {
    let s = spec(Algorithm::Distributed, Scheme::Weighted);
    let mut rng = Pcg64::seed_from(1);
    let mut data_rng = Pcg64::seed_from(s.seed);
    let data = distclus::coordinator::run_once(
        &s,
        &distclus::data::by_name("synthetic")
            .unwrap()
            .generate(&mut data_rng, s.scale),
        &RustBackend,
        &mut rng,
    )
    .unwrap();
    assert!(data.coreset.size() >= s.t);
    assert!(data.comm_points > 0);
    assert_eq!(data.centers.n(), s.k);
    let _ = run_once; // silence unused-import style lints on some setups
}

#[test]
fn star_topology_acts_as_central_coordinator() {
    // With a star, flooding is 2 hops and communication is low relative
    // to a dense random graph at the same t.
    let mut s = spec(Algorithm::Distributed, Scheme::Uniform);
    s.topology = TopologySpec::Star { n: 8 };
    let star = run_experiment(&s, &RustBackend).unwrap();
    s.topology = TopologySpec::Random { n: 8, p: 0.9 };
    let dense = run_experiment(&s, &RustBackend).unwrap();
    assert!(star.comm.mean < dense.comm.mean);
}
