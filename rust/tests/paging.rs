//! Paged streaming message plane — property tests and the
//! bounded-memory acceptance criteria.
//!
//! Properties pinned here:
//! 1. paged flood reassembly is order-invariant (pages interleave across
//!    sites and rounds, portions reconstruct bit-exactly);
//! 2. reassembly is loss-retry-invariant (reliable flooding under loss
//!    retransmits individual pages; portions still reconstruct);
//! 3. paging never changes the points-transmitted total;
//! 4. with a link capacity, `peak_points` of the paged exchange is
//!    strictly below the monolithic peak at `t ≥ 4 · page_points`, and
//!    ≤ 25% of it at the acceptance operating point
//!    (`page_points = 64`, `t = 2048`);
//! 5. final centers are bit-identical to the unpaged run at any thread
//!    count.

use distclus::clustering::backend::RustBackend;
use distclus::coreset::DistributedConfig;
use distclus::exec::ExecPolicy;
use distclus::network::{paginate, reassemble, ChannelConfig, LinkModel, Network, Payload};
use distclus::partition::Scheme;
use distclus::points::WeightedSet;
use distclus::prop_assert;
use distclus::protocol::{flood_multi, flood_reliable_multi, RunResult};
use distclus::scenario::{Distributed, Scenario};
use distclus::testutil::{arb_connected_graph, arb_portion, for_all, mixture_sites};
use std::sync::Arc;

#[test]
fn prop_paged_flood_reassembly_is_order_invariant() {
    for_all(
        25,
        71,
        |rng| {
            let g = arb_connected_graph(rng, 12);
            let portions: Vec<Arc<WeightedSet>> =
                (0..g.n()).map(|_| arb_portion(rng, 40, 3)).collect();
            let page_points = 1 + rng.below(16);
            let capacity = if rng.below(2) == 0 { 0 } else { 1 + rng.below(24) };
            (g, portions, page_points, capacity)
        },
        |(g, portions, page_points, capacity)| {
            let origins: Vec<Vec<Payload>> = portions
                .iter()
                .enumerate()
                .map(|(i, p)| paginate(i, p.clone(), *page_points))
                .collect();
            let mut net = Network::new(g.clone())
                .without_transcript()
                .with_link_model(LinkModel::capped(*capacity));
            let held = flood_multi(&mut net, origins);
            let total: usize = portions.iter().map(|p| p.n()).sum();
            prop_assert!(
                net.cost_points() == 2 * g.m() * total,
                "paged flood cost {} != 2m·Σ|D| = {}",
                net.cost_points(),
                2 * g.m() * total
            );
            // Every node — wherever it sits, however pages interleaved —
            // reconstructs every portion bit-exactly.
            for (v, h) in held.iter().enumerate() {
                let back = reassemble(h).map_err(|e| format!("node {v}: {e}"))?;
                prop_assert!(back.len() == g.n(), "node {v} missing portions");
                for (site, set) in back {
                    prop_assert!(
                        set == *portions[site],
                        "node {v}: portion {site} corrupted"
                    );
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_paged_reassembly_is_loss_retry_invariant() {
    for_all(
        12,
        72,
        |rng| {
            let g = arb_connected_graph(rng, 9);
            let portions: Vec<Arc<WeightedSet>> =
                (0..g.n()).map(|_| arb_portion(rng, 24, 2)).collect();
            let page_points = 1 + rng.below(8);
            let loss = 0.1 + 0.2 * rng.uniform();
            let seed = rng.next_u64();
            (g, portions, page_points, loss, seed)
        },
        |(g, portions, page_points, loss, seed)| {
            let origins: Vec<Vec<Payload>> = portions
                .iter()
                .enumerate()
                .map(|(i, p)| paginate(i, p.clone(), *page_points))
                .collect();
            let mut net = Network::new(g.clone())
                .without_transcript()
                .with_loss(*loss, *seed);
            let held = flood_reliable_multi(&mut net, origins, 100_000);
            for (v, h) in held.iter().enumerate() {
                let back = reassemble(h).map_err(|e| format!("node {v}: {e}"))?;
                for (site, set) in back {
                    prop_assert!(
                        set == *portions[site],
                        "node {v}: portion {site} torn after retransmission"
                    );
                }
            }
            Ok(())
        },
    );
}

fn pipeline_sites(seed: u64, sites: usize, points: usize) -> Vec<WeightedSet> {
    mixture_sites(seed, points, 4, 4, sites, Scheme::Uniform, false)
}

fn graph_run(
    g: &distclus::topology::Graph,
    locals: &[WeightedSet],
    cfg: &DistributedConfig,
    channel: ChannelConfig,
    exec: ExecPolicy,
) -> RunResult {
    Scenario::on_graph(g.clone())
        .channel(channel)
        .exec(exec)
        .seed(1234)
        .run(&Distributed(*cfg), locals, &RustBackend)
        .unwrap()
}

#[test]
fn paged_peak_strictly_below_monolithic_at_4x_page_boundary() {
    // The satellite bound at its weakest point: t exactly 4·page_points.
    // On a star the monolithic exchange funnels every portion through
    // the hub's inbox (and back out), so the memory gap is structural.
    let page = 32;
    let locals = pipeline_sites(5, 5, 2_000);
    let g = distclus::topology::generators::star(5);
    let cfg = DistributedConfig {
        t: 4 * page,
        k: 4,
        ..Default::default()
    };
    let mono = graph_run(&g, &locals, &cfg, ChannelConfig::default(), ExecPolicy::Sequential);
    let paged = graph_run(
        &g,
        &locals,
        &cfg,
        ChannelConfig::uniform(page, page),
        ExecPolicy::Sequential,
    );
    assert_eq!(mono.comm_points, paged.comm_points);
    assert_eq!(mono.centers, paged.centers);
    assert!(
        paged.peak_points < mono.peak_points,
        "paged {} !< mono {}",
        paged.peak_points,
        mono.peak_points
    );
}

#[test]
fn acceptance_paged_peak_quarter_of_monolithic_at_t2048() {
    // The PR acceptance criterion: page_points = 64, t = 2048 — the
    // paged exchange must hold peak receiver memory at ≤ 25% of the
    // monolithic exchange on the same seed/topology, at identical total
    // communication (the exact 2m(t + nk) formula; page metadata rides
    // free so there is no header term) and bit-identical centers at any
    // thread count.
    let locals = pipeline_sites(8, 5, 4_000);
    let g = distclus::topology::generators::complete(5);
    let cfg = DistributedConfig {
        t: 2048,
        k: 4,
        ..Default::default()
    };
    let channel = ChannelConfig::uniform(64, 64);
    let mono = graph_run(&g, &locals, &cfg, ChannelConfig::default(), ExecPolicy::Sequential);
    let paged = graph_run(&g, &locals, &cfg, channel.clone(), ExecPolicy::Sequential);

    // Exact Theorem-2 communication, invariant under paging.
    let expected = 2 * g.m() * g.n() + 2 * g.m() * (cfg.t + g.n() * cfg.k);
    assert_eq!(mono.comm_points, expected);
    assert_eq!(paged.comm_points, expected);

    // Bounded memory: ≤ 25% of the monolithic peak.
    assert!(
        4 * paged.peak_points <= mono.peak_points,
        "paged peak {} > 25% of monolithic peak {}",
        paged.peak_points,
        mono.peak_points
    );

    // Bit-identical results at any thread count, paged or not. (The
    // sequential policy has its own RNG stream structure, so cross-policy
    // equality is not expected — invariance holds across parallel
    // worker counts.)
    assert_eq!(mono.coreset.set, paged.coreset.set);
    assert_eq!(mono.centers, paged.centers);
    let p2 = graph_run(
        &g,
        &locals,
        &cfg,
        channel.clone(),
        ExecPolicy::parallel(2),
    );
    let m2 = graph_run(
        &g,
        &locals,
        &cfg,
        ChannelConfig::default(),
        ExecPolicy::parallel(2),
    );
    let p8 = graph_run(&g, &locals, &cfg, channel, ExecPolicy::parallel(8));
    assert_eq!(p2.centers, m2.centers, "paged == monolithic at 2 threads");
    assert_eq!(p2.coreset.set, m2.coreset.set);
    assert_eq!(p2.comm_points, expected);
    assert_eq!(p2.centers, p8.centers, "thread-count invariance");
    assert_eq!(p2.coreset.set, p8.coreset.set);
    assert_eq!(p2.rounds, p8.rounds, "rounds thread-invariant");
    assert_eq!(p2.peak_points, p8.peak_points, "peak thread-invariant");
    assert!(
        4 * p2.peak_points <= m2.peak_points,
        "≤25% bound must hold under the parallel engine too"
    );
}

#[test]
fn paged_tree_pipeline_bounds_peak_too() {
    let locals = pipeline_sites(9, 6, 3_000);
    let g = distclus::topology::generators::path(6);
    let tree = distclus::topology::SpanningTree::bfs(&g, 0);
    let cfg = DistributedConfig {
        t: 1024,
        k: 4,
        ..Default::default()
    };
    let run_at = |channel: ChannelConfig| {
        Scenario::on_tree(tree.clone())
            .channel(channel)
            .seed(77)
            .run(&Distributed(cfg), &locals, &RustBackend)
            .unwrap()
    };
    let mono = run_at(ChannelConfig::default());
    let paged = run_at(ChannelConfig::uniform(32, 32));
    assert_eq!(mono.comm_points, paged.comm_points);
    assert_eq!(mono.centers, paged.centers);
    assert!(
        paged.peak_points < mono.peak_points,
        "tree paged {} !< mono {}",
        paged.peak_points,
        mono.peak_points
    );
    assert!(paged.rounds > mono.rounds);
}
