//! Cross-backend parity: the AOT Pallas/XLA backend must agree with the
//! pure-Rust oracle backend on random instances across the supported
//! shape envelope — including the padding edges (d or k exactly at an
//! artifact boundary, chunk-straddling n).
//!
//! These tests are skipped (with a note) when `artifacts/` has not been
//! built; `make artifacts && cargo test` runs them.

use distclus::clustering::backend::{Backend, RustBackend};
use distclus::clustering::{approx_solution, Objective};
use distclus::points::{Dataset, WeightedSet};
use distclus::rng::Pcg64;
use distclus::runtime::XlaBackend;
use std::path::Path;

fn xla() -> Option<XlaBackend> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match XlaBackend::load(&dir) {
        Ok(b) => Some(b),
        Err(e) => {
            eprintln!("skipping xla parity tests: {e} (run `make artifacts`)");
            None
        }
    }
}

fn instance(rng: &mut Pcg64, n: usize, d: usize, k: usize) -> (Dataset, Vec<f64>, Dataset) {
    let data = distclus::data::synthetic::gaussian_mixture(rng, n, d, k.max(2));
    let weights: Vec<f64> = (0..data.n()).map(|_| rng.uniform() * 3.0 + 0.01).collect();
    let mut centers = Dataset::with_capacity(k, d);
    for _ in 0..k {
        let c: Vec<f32> = (0..d).map(|_| 2.0 * rng.normal() as f32).collect();
        centers.push(&c);
    }
    (data, weights, centers)
}

fn check_parity(xla: &XlaBackend, n: usize, d: usize, k: usize, seed: u64) {
    let mut rng = Pcg64::seed_from(seed);
    let (points, weights, centers) = instance(&mut rng, n, d, k);
    let a = xla.assign(&points, &weights, &centers);
    let b = RustBackend.assign(&points, &weights, &centers);
    assert_eq!(a.assign.len(), points.n());
    // Assignments may differ on exact ties only; costs must agree.
    let (ta, tb): (f64, f64) = (a.kmeans_cost.iter().sum(), b.kmeans_cost.iter().sum());
    assert!(
        (ta - tb).abs() / tb.max(1e-12) < 1e-3,
        "n={n} d={d} k={k}: kmeans total {ta} vs {tb}"
    );
    let (ma, mb): (f64, f64) = (a.kmedian_cost.iter().sum(), b.kmedian_cost.iter().sum());
    assert!(
        (ma - mb).abs() / mb.max(1e-12) < 1e-3,
        "n={n} d={d} k={k}: kmedian total {ma} vs {mb}"
    );
    let sa = xla.lloyd_step(&points, &weights, &centers);
    let sb = RustBackend.lloyd_step(&points, &weights, &centers);
    for c in 0..k {
        assert!(
            (sa.counts[c] - sb.counts[c]).abs() < 1e-2 * (1.0 + sb.counts[c]),
            "count[{c}]"
        );
        for j in 0..d {
            let (x, y) = (sa.sums[c * d + j], sb.sums[c * d + j]);
            assert!(
                (x - y).abs() < 1e-2 * (1.0 + y.abs()),
                "sums[{c},{j}]: {x} vs {y}"
            );
        }
    }
}

#[test]
fn parity_across_shape_envelope() {
    let Some(xla) = xla() else { return };
    // (n, d, k): interior, chunk boundary (1024), straddle, artifact
    // boundaries d=16/32/64/96/128, k=8/16/64.
    for (i, &(n, d, k)) in [
        (100usize, 4usize, 3usize),
        (1024, 16, 8),
        (1025, 16, 9),
        (2048, 10, 5),
        (3000, 32, 16),
        (500, 33, 16),
        (700, 64, 16),
        (650, 90, 50),
        (300, 128, 64),
        (64, 1, 1),
    ]
    .iter()
    .enumerate()
    {
        check_parity(&xla, n, d, k, 1_000 + i as u64);
    }
}

#[test]
fn parity_on_unsupported_shapes_falls_back() {
    let Some(xla) = xla() else { return };
    // d > 128 exceeds every artifact: the backend must still answer
    // correctly (pure-Rust fallback).
    let mut rng = Pcg64::seed_from(9);
    let (points, weights, centers) = instance(&mut rng, 200, 150, 4);
    let a = xla.assign(&points, &weights, &centers);
    let b = RustBackend.assign(&points, &weights, &centers);
    assert_eq!(a.assign, b.assign);
}

#[test]
fn full_lloyd_converges_identically_enough_for_equal_solutions() {
    // Run the complete weighted-Lloyd solver on both backends from the
    // same seed: final costs must agree to f32-kernel tolerance.
    let Some(xla) = xla() else { return };
    let mut rng = Pcg64::seed_from(17);
    let data = distclus::data::synthetic::gaussian_mixture(&mut rng, 3_000, 12, 6);
    let set = WeightedSet::unit(data);
    let mut rng_a = Pcg64::seed_from(5);
    let mut rng_b = Pcg64::seed_from(5);
    let sol_rust = approx_solution(&set, 6, Objective::KMeans, &RustBackend, &mut rng_a, 25);
    let sol_xla = approx_solution(&set, 6, Objective::KMeans, &xla, &mut rng_b, 25);
    let rel = (sol_rust.cost - sol_xla.cost).abs() / sol_rust.cost;
    assert!(
        rel < 5e-2,
        "lloyd end-state diverged: rust {} xla {}",
        sol_rust.cost,
        sol_xla.cost
    );
}

#[test]
fn distributed_pipeline_runs_on_xla_backend() {
    let Some(xla) = xla() else { return };
    use distclus::coreset::DistributedConfig;
    use distclus::partition::Scheme;
    use distclus::protocol::cluster_on_graph;
    use distclus::topology::generators;
    let mut rng = Pcg64::seed_from(23);
    let data = distclus::data::synthetic::gaussian_mixture(&mut rng, 4_000, 10, 5);
    let g = generators::grid(2, 3);
    let locals: Vec<WeightedSet> = Scheme::Weighted
        .partition_on(&data, &g, &mut rng)
        .into_iter()
        .map(WeightedSet::unit)
        .collect();
    let run = cluster_on_graph(
        &g,
        &locals,
        &DistributedConfig {
            t: 500,
            k: 5,
            ..Default::default()
        },
        &xla,
        &mut rng,
    )
    .unwrap();
    assert_eq!(run.centers.n(), 5);
    let global = WeightedSet::unit(data);
    let direct = approx_solution(&global, 5, Objective::KMeans, &xla, &mut rng, 30);
    let ratio =
        distclus::clustering::cost_of(&global, &run.centers, Objective::KMeans) / direct.cost;
    assert!(ratio < 1.3, "xla-backend pipeline ratio {ratio}");
}
