//! Layout-invariance suite: the determinism contract across kernel
//! memory layouts.
//!
//! Contract under test (see `distclus::clustering::layout`): every
//! `KernelLayout` variant of the parallel backend — AoS scalar, SoA
//! vectorized, SoA with Hilbert or Morton pre-ordering — produces an
//! `Assignment` bit-identical to the scalar `RustBackend` oracle at any
//! worker-thread count. The curve reorder is applied before blocking
//! and inverted on output, so callers never observe it; the SoA lane
//! kernel replicates the scalar kernel's f32 summation tree exactly, so
//! argmin, lowest-index tie-breaks and both cost vectors match to the
//! bit, not just to a tolerance.

use distclus::clustering::backend::{Backend, ParallelBackend, RustBackend};
use distclus::clustering::layout::{hilbert_order, invert_permutation, morton_order, ALL_LAYOUTS};
use distclus::points::Dataset;
use distclus::prop_assert;
use distclus::rng::Pcg64;
use distclus::testutil::{for_all, kernel_instance};

const THREADS: [usize; 3] = [1, 2, 8];

#[test]
fn assignment_bit_identical_across_layouts_and_threads() {
    // Random shapes, d deliberately spanning "not a multiple of the
    // 8-lane width" and k spanning one vs many center blocks.
    for_all(
        12,
        17,
        |rng| {
            let n = 50 + rng.below(1_200);
            let d = 1 + rng.below(40);
            let k = 1 + rng.below(200);
            let (points, weights, centers) = kernel_instance(rng, n, d, k);
            (points, weights, centers)
        },
        |(points, weights, centers)| {
            let oracle = RustBackend.assign(points, weights, centers);
            for layout in ALL_LAYOUTS {
                for threads in THREADS {
                    let backend = ParallelBackend::new(threads).layout(layout);
                    let got = backend.assign(points, weights, centers);
                    prop_assert!(
                        got.assign == oracle.assign,
                        "argmin diverged: layout {} threads {threads} (n={} d={} k={})",
                        layout.name(),
                        points.n(),
                        points.d,
                        centers.n()
                    );
                    prop_assert!(
                        got.kmeans_cost == oracle.kmeans_cost,
                        "kmeans costs diverged: layout {} threads {threads}",
                        layout.name()
                    );
                    prop_assert!(
                        got.kmedian_cost == oracle.kmedian_cost,
                        "kmedian costs diverged: layout {} threads {threads}",
                        layout.name()
                    );
                }
            }
            Ok(())
        },
    );
}

#[test]
fn tie_heavy_instances_break_ties_to_the_lowest_index() {
    // Integer-grid points with every center duplicated and most points
    // sitting exactly on a center: distances tie exactly in f32, so any
    // deviation from the scalar strict-< scan order shows up here.
    let mut rng = Pcg64::seed_from(23);
    let d = 11; // not a multiple of the lane width
    let k = 24;
    let mut centers = Dataset::with_capacity(2 * k, d);
    let mut base = Vec::new();
    for _ in 0..k {
        let c: Vec<f32> = (0..d).map(|_| rng.below(4) as f32).collect();
        base.push(c);
    }
    for c in &base {
        centers.push(c);
    }
    for c in &base {
        centers.push(c); // duplicate block: indices k..2k never win
    }
    let n = 900;
    let mut points = Dataset::with_capacity(n, d);
    for i in 0..n {
        if i % 3 == 0 {
            // Off-grid point: ties only through coordinate symmetry.
            let p: Vec<f32> = (0..d).map(|_| rng.below(4) as f32 + 0.5).collect();
            points.push(&p);
        } else {
            // Exactly on a (duplicated) center.
            points.push(&base[rng.below(k)]);
        }
    }
    let weights = vec![1.0f64; n];
    let oracle = RustBackend.assign(&points, &weights, &centers);
    assert!(
        oracle.assign.iter().all(|&c| (c as usize) < k),
        "oracle must already break ties below the duplicate block"
    );
    for layout in ALL_LAYOUTS {
        for threads in THREADS {
            let backend = ParallelBackend::new(threads).layout(layout);
            let got = backend.assign(&points, &weights, &centers);
            assert_eq!(
                got.assign,
                oracle.assign,
                "tie-break diverged: layout {} threads {threads}",
                layout.name()
            );
            assert_eq!(got.kmeans_cost, oracle.kmeans_cost);
            assert_eq!(got.kmedian_cost, oracle.kmedian_cost);
        }
    }
}

#[test]
fn lloyd_step_bit_identical_across_layouts_and_threads() {
    let mut rng = Pcg64::seed_from(31);
    let (points, weights, centers) = kernel_instance(&mut rng, 4_000, 21, 48);
    let oracle = RustBackend.lloyd_step(&points, &weights, &centers);
    for layout in ALL_LAYOUTS {
        for threads in THREADS {
            let backend = ParallelBackend::new(threads).layout(layout);
            let got = backend.lloyd_step(&points, &weights, &centers);
            assert_eq!(got.sums, oracle.sums, "layout {}", layout.name());
            assert_eq!(got.counts, oracle.counts, "layout {}", layout.name());
            assert_eq!(got.cost, oracle.cost, "layout {}", layout.name());
        }
    }
}

#[test]
fn curve_orders_round_trip_on_known_grids() {
    // 2D: a 4x4 grid; 3D: a 3x3x3 grid. Both curve orders must be true
    // permutations whose inverse composes to the identity.
    let mut grid2 = Dataset::with_capacity(16, 2);
    for y in 0..4 {
        for x in 0..4 {
            grid2.push(&[x as f32, y as f32]);
        }
    }
    let mut grid3 = Dataset::with_capacity(27, 3);
    for z in 0..3 {
        for y in 0..3 {
            for x in 0..3 {
                grid3.push(&[x as f32, y as f32, z as f32]);
            }
        }
    }
    for points in [&grid2, &grid3] {
        for order in [hilbert_order(points), morton_order(points)] {
            let mut seen = vec![false; points.n()];
            for &i in &order {
                assert!(!seen[i], "duplicate index {i} in curve order");
                seen[i] = true;
            }
            let inv = invert_permutation(&order);
            for (pos, &i) in order.iter().enumerate() {
                assert_eq!(inv[i], pos, "perm o inv-perm != id at {i}");
            }
        }
    }
    // Hilbert on the 4x4 grid is a unit-step walk: consecutive points
    // are grid neighbours (the locality the SoA tiles bank on).
    let order = hilbert_order(&grid2);
    for w in order.windows(2) {
        let (a, b) = (grid2.row(w[0]), grid2.row(w[1]));
        let l1 = (a[0] - b[0]).abs() + (a[1] - b[1]).abs();
        assert_eq!(l1, 1.0, "hilbert walk must step one cell at a time");
    }
}
