//! Equivalence suite for the sparse topology + event-driven engine:
//!
//! 1. CSR construction parity: `Graph::from_edges`, incremental
//!    `add_edge` and the streaming `GraphBuilder` produce identical
//!    graphs, with sorted zero-alloc neighbor slices and edge-id
//!    roundtrips;
//! 2. the active-set drive loop is *bit-identical* to the dense
//!    reference loop — same transcripts, comm totals, rounds, drops and
//!    held payloads — across topology families (Erdős–Rényi, grid,
//!    power-law, random tree), link capacities and loss;
//! 3. the same holds end-to-end through `Scenario` for every topology
//!    axis (graph / drawn tree / overlay / composed Zhang) and thread
//!    count, with only the `sched_ticks` meter allowed to differ;
//! 4. the active-set scheduler never polls an idle inbox (the counter
//!    contract behind its O(active frontier) round cost).

use distclus::clustering::backend::RustBackend;
use distclus::clustering::Objective;
use distclus::coreset::zhang::ZhangConfig;
use distclus::coreset::DistributedConfig;
use distclus::network::{paginate, LinkModel, Network, Payload};
use distclus::partition::Scheme;
use distclus::prop_assert;
use distclus::protocol::{flood_multi_mode, DriveMode, RunResult};
use distclus::rng::Pcg64;
use distclus::scenario::{CoresetAlgorithm, Distributed, Scenario, Zhang};
use distclus::sketch::SketchPlan;
use distclus::testutil::{for_all, mixture_sites, unit_portion};
use distclus::topology::{generators, Graph, GraphBuilder};
use distclus::trace::keys;

#[test]
fn csr_construction_parity_across_entry_points() {
    for_all(
        40,
        101,
        |rng| {
            let n = 2 + rng.below(40);
            // Random edge list with duplicates and both orientations —
            // every entry point must normalize to the same CSR.
            let edges: Vec<(usize, usize)> = (0..3 * n)
                .filter_map(|_| {
                    let u = rng.below(n);
                    let v = rng.below(n);
                    (u != v).then_some((u, v))
                })
                .collect();
            (n, edges)
        },
        |(n, edges)| {
            let from = Graph::from_edges(*n, edges);
            let mut incremental = Graph::empty(*n);
            let mut builder = GraphBuilder::new(*n);
            for &(u, v) in edges {
                incremental.add_edge(u, v);
                builder.add_edge(u, v);
            }
            prop_assert!(from == incremental, "from_edges != add_edge at n={n}");
            prop_assert!(from == builder.build(), "from_edges != builder at n={n}");

            let mut directed = 0usize;
            for u in 0..*n {
                let nb = from.neighbors(u);
                prop_assert!(
                    nb.windows(2).all(|w| w[0] < w[1]),
                    "neighbors of {u} must be sorted and deduplicated: {nb:?}"
                );
                prop_assert!(from.degree(u) == nb.len(), "degree mismatch at {u}");
                for &v in nb {
                    let Some(eid) = from.edge_id(u, v) else {
                        return Err(format!("present edge ({u},{v}) has no id"));
                    };
                    prop_assert!(
                        from.edge_endpoints(eid) == (u, v),
                        "edge id {eid} does not round-trip to ({u},{v})"
                    );
                    directed += 1;
                }
            }
            prop_assert!(
                directed == from.directed_edges() && directed == 2 * from.m(),
                "directed-edge count mismatch: {directed} vs m={}",
                from.m()
            );
            let listed: Vec<(usize, usize)> = from.edges_iter().collect();
            prop_assert!(listed == from.edges(), "edges_iter disagrees with edges()");
            prop_assert!(listed.len() == from.m(), "edges_iter length != m");
            Ok(())
        },
    );
}

/// Per-node origin sets for the flood equivalence runs: every node
/// floods its cost scalar; `paged` adds a small paged portion on top.
fn flood_origins(rng: &mut Pcg64, n: usize, paged: bool) -> Vec<Vec<Payload>> {
    (0..n)
        .map(|i| {
            let mut own = vec![Payload::LocalCost {
                site: i,
                cost: i as f64,
            }];
            if paged {
                own.extend(paginate(i, unit_portion(rng, 5 + rng.below(20), 3), 8));
            }
            own
        })
        .collect()
}

#[test]
fn flood_active_set_is_bit_identical_to_dense() {
    for_all(
        16,
        201,
        |rng| {
            let graph = match rng.below(4) {
                0 => generators::erdos_renyi_connected(rng, 8 + rng.below(16), 0.3),
                1 => generators::grid(2 + rng.below(3), 3 + rng.below(4)),
                2 => generators::power_law_connected(rng, 20 + rng.below(30), 4.0, 2.5),
                _ => generators::random_tree(rng, 6 + rng.below(20)),
            };
            let cap = [0usize, 4][rng.below(2)];
            let loss = if rng.below(3) == 0 { Some((0.3, 7u64)) } else { None };
            let origins = flood_origins(rng, graph.n(), rng.below(2) == 0);
            (graph, cap, loss, origins)
        },
        |(graph, cap, loss, origins)| {
            let run = |mode: DriveMode| {
                let mut net =
                    Network::new(graph.clone()).with_link_model(LinkModel::capped(*cap));
                if let Some((p, seed)) = loss {
                    net = net.with_loss(*p, *seed);
                }
                let held = flood_multi_mode(&mut net, origins.clone(), mode);
                (held, net)
            };
            let (held_a, net_a) = run(DriveMode::ActiveSet);
            let (held_d, net_d) = run(DriveMode::Dense);
            prop_assert!(held_a == held_d, "held payloads diverge");
            prop_assert!(
                net_a.transcript() == net_d.transcript(),
                "transcripts diverge on n={} cap={cap} loss={loss:?}",
                graph.n()
            );
            prop_assert!(net_a.cost_points() == net_d.cost_points(), "comm diverges");
            prop_assert!(net_a.round() == net_d.round(), "rounds diverge");
            prop_assert!(net_a.dropped() == net_d.dropped(), "drops diverge");
            prop_assert!(
                net_a.peak_points() == net_d.peak_points(),
                "peaks diverge"
            );
            prop_assert!(
                net_a.idle_recvs() == 0,
                "active-set mode polled {} idle inboxes",
                net_a.idle_recvs()
            );
            Ok(())
        },
    );
}

#[test]
fn scenario_drive_modes_are_bit_identical_for_every_topology_and_thread_count() {
    let n = 8usize;
    let locals = mixture_sites(301, 4_000, 4, 4, n, Scheme::Uniform, false);
    let mut rng0 = Pcg64::seed_from(302);
    let g = generators::erdos_renyi_connected(&mut rng0, n, 0.35);
    let cfg = DistributedConfig {
        t: 384,
        k: 3,
        ..Default::default()
    };
    let distributed = Distributed(cfg);
    let zhang = Zhang(ZhangConfig {
        t_node: 60,
        k: 3,
        objective: Objective::KMeans,
    });
    let cases: Vec<(&str, Scenario, &dyn CoresetAlgorithm)> = vec![
        (
            "graph",
            Scenario::on_graph(g.clone())
                .page_points(32)
                .links(LinkModel::capped(48)),
            &distributed,
        ),
        (
            "tree",
            Scenario::on_spanning_tree_of(g.clone()).page_points(32),
            &distributed,
        ),
        (
            "overlay",
            Scenario::on_overlay_of(g.clone())
                .page_points(32)
                .sketch(SketchPlan::merge_reduce(128)),
            &distributed,
        ),
        ("zhang", Scenario::on_spanning_tree_of(g.clone()), &zhang),
    ];
    let mut some_case_scheduled_strictly_less = false;
    for (label, base, algo) in cases {
        let dense: RunResult = base
            .clone()
            .drive_mode(DriveMode::Dense)
            .seed(9)
            .run(algo, &locals, &RustBackend)
            .unwrap();
        for threads in [1usize, 2, 8] {
            let active = base
                .clone()
                .threads(threads)
                .seed(9)
                .run(algo, &locals, &RustBackend)
                .unwrap();
            assert_eq!(active.centers, dense.centers, "{label} threads={threads}");
            assert_eq!(active.coreset.set, dense.coreset.set, "{label}");
            assert_eq!(active.comm_points, dense.comm_points, "{label}");
            assert_eq!(active.rounds, dense.rounds, "{label}");
            assert_eq!(active.peak_points, dense.peak_points, "{label}");
            assert_eq!(active.node_peaks, dense.node_peaks, "{label}");
            // Error accounting must not depend on the scheduler.
            for key in [keys::MR_ERROR_PPM, keys::MR_REDUCTIONS] {
                assert_eq!(
                    active.meters.get(key),
                    dense.meters.get(key),
                    "{label}: {key}"
                );
            }
            // The one sanctioned difference: scheduled work.
            let (a, d) = (
                active.meters[keys::SCHED_TICKS],
                dense.meters[keys::SCHED_TICKS],
            );
            assert!(a <= d, "{label}: active scheduled {a} > dense {d}");
            some_case_scheduled_strictly_less |= a < d;
        }
    }
    assert!(
        some_case_scheduled_strictly_less,
        "the active-set scheduler saved no work on any topology"
    );
}

#[test]
fn active_mode_never_polls_idle_inboxes() {
    // One origin at node 0 of a long path: the frontier is one or two
    // nodes wide while the dense loop re-scans all 64 inboxes per round.
    let n = 64usize;
    let g = generators::path(n);
    let origins: Vec<Vec<Payload>> = (0..n)
        .map(|i| {
            if i == 0 {
                vec![Payload::LocalCost { site: 0, cost: 1.0 }]
            } else {
                Vec::new()
            }
        })
        .collect();
    let run = |mode: DriveMode| {
        let mut net = Network::new(g.clone()).without_transcript();
        let held = flood_multi_mode(&mut net, origins.clone(), mode);
        assert!(held.iter().all(|h| h.len() == 1), "payload must reach everyone");
        (net.idle_recvs(), net.recv_drains(), net.round())
    };
    let (idle_active, drains_active, rounds_active) = run(DriveMode::ActiveSet);
    let (idle_dense, drains_dense, rounds_dense) = run(DriveMode::Dense);
    assert_eq!(rounds_active, rounds_dense, "schedulers must agree on rounds");
    assert_eq!(
        drains_active, drains_dense,
        "both modes drain exactly the real deliveries"
    );
    assert_eq!(idle_active, 0, "active-set polled an idle inbox");
    assert!(
        idle_dense > 100,
        "dense must have paid the idle scans this test contrasts ({idle_dense})"
    );
}
