//! Property tests for the paper's central claim (Theorem 1): the
//! distributed construction yields an ε-coreset — its weighted cost
//! tracks the true cost for arbitrary center sets — across random data,
//! random partitions and random topologies; plus invariants of the
//! budget allocation and the baselines.

use distclus::clustering::backend::{Backend, RustBackend};
use distclus::clustering::Objective;
use distclus::coreset::combine::{self, CombineConfig};
use distclus::coreset::distributed::{self, allocate_budget, DistributedConfig};
use distclus::coreset::zhang::{self, ZhangConfig};
use distclus::partition::Scheme;
use distclus::points::{Dataset, WeightedSet};
use distclus::prop_assert;
use distclus::rng::Pcg64;
use distclus::testutil::for_all;
use distclus::topology::{generators, SpanningTree};

struct Instance {
    locals: Vec<WeightedSet>,
    global: WeightedSet,
    k: usize,
    seed: u64,
}

fn gen_instance(rng: &mut Pcg64) -> Instance {
    let d = 2 + rng.below(6);
    let k = 2 + rng.below(4);
    let n = 2_000 + rng.below(4_000);
    let sites = 2 + rng.below(6);
    let data = distclus::data::synthetic::gaussian_mixture(rng, n, d, k);
    let scheme = [Scheme::Uniform, Scheme::Similarity, Scheme::Weighted][rng.below(3)];
    let locals: Vec<WeightedSet> = scheme
        .partition(&data, sites, rng)
        .unwrap()
        .into_iter()
        .filter(|p| p.n() > 0)
        .map(WeightedSet::unit)
        .collect();
    let global = WeightedSet::union(locals.iter());
    Instance {
        locals,
        global,
        k,
        seed: rng.next_u64(),
    }
}

fn probe_centers(rng: &mut Pcg64, k: usize, d: usize, global: &WeightedSet) -> Dataset {
    // Mix of data points and random Gaussians: covers both the "near the
    // data" and "far from the data" regimes of Definition 1's ∀x.
    let mut out = Dataset::with_capacity(k, d);
    for _ in 0..k {
        if rng.uniform() < 0.5 && global.n() > 0 {
            out.push(global.points.row(rng.below(global.n())));
        } else {
            let c: Vec<f32> = (0..d).map(|_| 3.0 * rng.normal() as f32).collect();
            out.push(&c);
        }
    }
    out
}

#[test]
fn prop_distributed_coreset_distortion_bounded() {
    for_all(8, 101, gen_instance, |inst| {
        let mut rng = Pcg64::seed_from(inst.seed);
        let cfg = DistributedConfig {
            t: 2_500,
            k: inst.k,
            clamp_center_weights: false,
            ..Default::default()
        };
        let portions =
            distributed::build_portions(&inst.locals, &cfg, &RustBackend, &mut rng);
        let coreset = distributed::union(&portions);
        for probe_i in 0..6 {
            let mut prng = Pcg64::seed_from(inst.seed ^ (probe_i + 1));
            let probe = probe_centers(&mut prng, inst.k, inst.global.d(), &inst.global);
            let truth =
                distclus::clustering::cost_of(&inst.global, &probe, Objective::KMeans);
            let est =
                distclus::clustering::cost_of(&coreset.set, &probe, Objective::KMeans);
            if truth > 1e-9 {
                let err = (est - truth).abs() / truth;
                prop_assert!(err < 0.35, "distortion {err} on probe {probe_i}");
            }
        }
        Ok(())
    });
}

#[test]
fn prop_coreset_mass_is_unbiased() {
    for_all(10, 202, gen_instance, |inst| {
        let mut rng = Pcg64::seed_from(inst.seed);
        let cfg = DistributedConfig {
            t: 1_500,
            k: inst.k,
            clamp_center_weights: false,
            ..Default::default()
        };
        let portions =
            distributed::build_portions(&inst.locals, &cfg, &RustBackend, &mut rng);
        let coreset = distributed::union(&portions);
        let ratio = coreset.set.total_weight() / inst.global.total_weight();
        prop_assert!((ratio - 1.0).abs() < 0.25, "mass ratio {ratio}");
        Ok(())
    });
}

#[test]
fn prop_budget_allocation_exact_and_proportional() {
    for_all(
        50,
        303,
        |rng| {
            let sites = 1 + rng.below(20);
            let t = rng.below(5_000);
            let costs: Vec<f64> = (0..sites)
                .map(|_| if rng.uniform() < 0.2 { 0.0 } else { rng.uniform() * 100.0 })
                .collect();
            (t, costs)
        },
        |(t, costs)| {
            let alloc = allocate_budget(*t, costs);
            prop_assert!(
                alloc.iter().sum::<usize>() == *t,
                "allocation sums to {} != {t}",
                alloc.iter().sum::<usize>()
            );
            let total: f64 = costs.iter().sum();
            if total > 0.0 {
                for (i, (&a, &c)) in alloc.iter().zip(costs).enumerate() {
                    let share = *t as f64 * c / total;
                    prop_assert!(
                        (a as f64 - share).abs() <= 1.0,
                        "site {i}: {a} vs share {share}"
                    );
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_combine_vs_distributed_same_budget_same_size() {
    for_all(6, 404, gen_instance, |inst| {
        let mut rng = Pcg64::seed_from(inst.seed);
        let t = 900;
        let d_portions = distributed::build_portions(
            &inst.locals,
            &DistributedConfig {
                t,
                k: inst.k,
                ..Default::default()
            },
            &RustBackend,
            &mut rng,
        );
        let c_portions = combine::build_portions(
            &inst.locals,
            &CombineConfig {
                t,
                k: inst.k,
                objective: Objective::KMeans,
            },
            &RustBackend,
            &mut rng,
        );
        let ds = distributed::union(&d_portions);
        let cs = distributed::union(&c_portions);
        prop_assert!(
            ds.size() == cs.size(),
            "sizes differ: alg1 {} vs combine {} (unfair comparison)",
            ds.size(),
            cs.size()
        );
        Ok(())
    });
}

#[test]
fn prop_zhang_composition_mass_and_size() {
    for_all(6, 505, gen_instance, |inst| {
        let mut rng = Pcg64::seed_from(inst.seed);
        let n = inst.locals.len();
        let g = generators::random_tree(&mut rng, n);
        let tree = SpanningTree::bfs(&g, rng.below(n));
        let cfg = ZhangConfig {
            t_node: 400,
            k: inst.k,
            objective: Objective::KMeans,
        };
        let res = zhang::build_on_tree(&inst.locals, &tree, &cfg, &RustBackend, &mut rng);
        prop_assert!(
            res.coreset.size() <= cfg.t_node + cfg.k + inst.global.n(),
            "root coreset too large: {}",
            res.coreset.size()
        );
        let ratio = res.coreset.set.total_weight() / inst.global.total_weight();
        prop_assert!((ratio - 1.0).abs() < 0.5, "mass ratio {ratio}");
        Ok(())
    });
}

#[test]
fn prop_assignment_per_point_costs_consistent() {
    // kmedian_cost^2 == kmeans_cost * weight for every point, any data.
    for_all(
        20,
        606,
        |rng| {
            let set = distclus::testutil::arb_weighted_set(rng, 300, 6);
            let k = 1 + rng.below(5);
            let centers = distclus::clustering::kmeanspp::seed(
                &set,
                k,
                Objective::KMeans,
                rng,
            );
            (set, centers)
        },
        |(set, centers)| {
            let asg = RustBackend.assign(&set.points, &set.weights, centers);
            for i in 0..set.n() {
                let w = set.weights[i];
                if w <= 0.0 {
                    continue;
                }
                let lhs = asg.kmedian_cost[i].powi(2);
                let rhs = asg.kmeans_cost[i] * w;
                prop_assert!(
                    (lhs - rhs).abs() <= 1e-6 * (1.0 + rhs.abs()),
                    "point {i}: {lhs} vs {rhs}"
                );
            }
            Ok(())
        },
    );
}
