//! Offline drop-in subset of the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this workspace
//! vendors the small slice of `anyhow`'s API the codebase actually uses:
//! [`Error`], [`Result`], the [`anyhow!`] / [`bail!`] / [`ensure!`]
//! macros and the [`Context`] extension trait. Error values carry a
//! stack of human-readable messages (outermost context first); the `?`
//! operator converts from any `std::error::Error`.
//!
//! Intentionally *not* implemented (unused here): downcasting,
//! backtraces, `source()` chains as trait objects.

use std::fmt;

/// A type-erased error: a stack of messages, outermost context first.
pub struct Error {
    msgs: Vec<String>,
}

/// `Result<T, anyhow::Error>`, with an overridable error type like the
/// real crate's alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            msgs: vec![message.to_string()],
        }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.msgs.insert(0, context.to_string());
        self
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(err: E) -> Error {
        Error::msg(&err)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msgs[0])
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msgs[0])?;
        if self.msgs.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for m in &self.msgs[1..] {
                write!(f, "\n    {m}")?;
            }
        }
        Ok(())
    }
}

/// Construct an [`Error`] from a format string (or anything displayable).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($t:tt)+) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($t)+));
        }
    };
}

/// Attach context to errors, like `anyhow::Context`.
pub trait Context<T> {
    /// Wrap the error with a fixed context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;

    /// Wrap the error with a lazily evaluated context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn macro_forms() {
        let a = anyhow!("plain");
        assert_eq!(a.to_string(), "plain");
        let x = 7;
        let b = anyhow!("inline {x}");
        assert_eq!(b.to_string(), "inline 7");
        let c = anyhow!("fmt {} and {}", 1, "two");
        assert_eq!(c.to_string(), "fmt 1 and two");
        let d = anyhow!(String::from("owned"));
        assert_eq!(d.to_string(), "owned");
    }

    #[test]
    fn bail_and_ensure() {
        fn f(fail: bool) -> Result<u32> {
            ensure!(!fail, "flag was {fail}");
            Ok(3)
        }
        assert_eq!(f(false).unwrap(), 3);
        assert_eq!(f(true).unwrap_err().to_string(), "flag was true");

        fn g() -> Result<()> {
            bail!("always {}", "fails")
        }
        assert_eq!(g().unwrap_err().to_string(), "always fails");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(f().unwrap_err().to_string().contains("missing thing"));
    }

    #[test]
    fn context_wraps_outermost_first() {
        let e: Result<()> = Err(io_err()).with_context(|| "reading config".to_string());
        let e = e.unwrap_err();
        assert_eq!(e.to_string(), "reading config");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by"), "{dbg}");
        assert!(dbg.contains("missing thing"), "{dbg}");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("nothing there").unwrap_err();
        assert_eq!(e.to_string(), "nothing there");
    }
}
